//! Autoregressive decode with a mixed-precision KV cache (paper §3.1/§5 +
//! Appendix G): after the sequence-parallel prefill, generation proceeds
//! on the device owning the sequence tail. That device's cache holds its
//! *local* prefill tokens in full precision and the other devices' tokens
//! as dequantized VQ codes — Appendix G's memory accounting.
//!
//! ## Fused batched decode
//!
//! [`step_batch`] advances any number of in-flight sessions through **one
//! GEMM per layer per iteration**: per-slot hidden states are gathered into
//! a `[batch, d_model]` activation matrix, the layer's LN/QKV/output/MLP
//! matmuls run once over the whole batch, and only attention (which reads
//! each slot's private cache) is per-slot. Every operator in
//! [`crate::tensor`] is row-independent with a fixed inner accumulation
//! order, so each batch row is bit-identical to the `[1, D]` serial step —
//! [`DecodeSession::step`] is literally `step_batch` on a 1-slot batch, and
//! the serial escape hatch in the serving layer is the same arithmetic
//! executed one slot at a time.
//!
//! ## Arena-backed shared blocks
//!
//! A session can cover its prompt prefix by *attaching* sealed
//! [`crate::kv::arena`] blocks ([`DecodeSession::attach_block`]): an `Arc`
//! clone instead of the row-copying [`DecodeSession::import_rows`]. Decode
//! attention and [`DecodeSession::export_rows`] resolve rows below the
//! attached watermark through the shared storage (same head-major layout,
//! same ascending-`i` order), so attach is bit-identical to import.

use anyhow::{bail, Result};

use crate::kv::arena::BlockRef;
use crate::model::native::{self, BlockWeights};
use crate::tensor::Tensor;

use super::cluster::Cluster;
use super::partition::TokenPartition;

/// Per-layer KV cache on the tail device: [H, S_max, dh] with `len` valid.
pub struct DecodeSession<'a> {
    cluster: &'a Cluster,
    k_cache: Vec<Tensor>,
    v_cache: Vec<Tensor>,
    /// sealed arena blocks covering rows `[0, attached_hi)`; reads below
    /// the watermark resolve here, the private tensors hold everything
    /// after it. The tensor rows under an attached block stay zero and
    /// unused — accounting (not the f32 arrays) is the modeled resource.
    attached: Vec<BlockRef>,
    attached_hi: usize,
    pub len: usize,
    pub s_max: usize,
    /// prompt length; rows `[0, len.min(prompt_len))` have been replayed
    /// (all of them at construction, except for deferred sessions, which
    /// receive the prompt chunk by chunk through [`Self::replay_range`])
    pub prompt_len: usize,
    pub generated: Vec<usize>,
    /// last prompt token id — the first decode step conditions on this
    /// (NOT token 0; see `conditioning_token`)
    pub prompt_tail: usize,
    /// prompt ids retained for deferred (chunked) replay; drained to empty
    /// once the whole prompt has been replayed. Chunked replay recomputes
    /// the full-precision prefix from these ids each chunk (compute for
    /// memory, like the recompute-style eviction path) instead of caching
    /// exact K/V rows — the mixed cache stays the only persistent
    /// allocation, so the KV accounting the admission gate sees is the
    /// whole live footprint.
    pending_prompt: Vec<usize>,
    /// positional-locality mode (prefix-cache serving): which prompt rows
    /// are full precision depends only on a token's absolute position
    /// inside the artifact's full window — NOT on this prompt's total
    /// length — so K/V rows are a pure function of the token-id prefix and
    /// block-aligned prefixes can be shared between sessions bit for bit
    /// ([`Self::export_rows`] / [`Self::attach_block`] /
    /// [`Self::import_rows`]). Accounting uses
    /// [`crate::model::kv_cache_bytes_astra_positional`]. Off (the
    /// default) preserves the classic prompt-scaled partition exactly.
    positional: bool,
    /// profile-weighted split override (heterogeneous serving): when set,
    /// classic-mode [`Self::local_range`] partitions this prompt
    /// proportionally to these per-device weights instead of scaling the
    /// cluster's even partition. Affects only *which* rows are held in
    /// full precision — never correctness — so sessions admitted under
    /// different plans coexist in one batch.
    split_weights: Option<Vec<f64>>,
}

/// Scale the cluster's token partition down to a `t`-token prompt: each
/// device keeps its proportional share (floor), and the *largest-share*
/// (fastest) device absorbs the rounding remainder — on a skewed fleet the
/// old tail-absorbs rule handed the extra tokens to whatever device
/// happened to sit last, which on a strong-skew profile is the slowest
/// straggler. Ties break toward the tail-most maximum, so even partitions
/// keep the historical tail-owns-remainder behavior bit for bit. For
/// `t == partition.total()` this reproduces the partition exactly.
pub fn prompt_partition(full: &TokenPartition, t: usize) -> TokenPartition {
    let total = full.total().max(1);
    let mut sizes: Vec<usize> = full.sizes.iter().map(|&s| s * t / total).collect();
    let used: usize = sizes.iter().sum();
    // max_by_key returns the last maximum, i.e. the tail-most tie
    let fastest =
        full.sizes.iter().enumerate().max_by_key(|&(_, &s)| s).map(|(i, _)| i).unwrap_or(0);
    sizes[fastest] += t - used;
    TokenPartition::explicit(sizes)
}

/// The token id the next decode step embeds: the most recently generated
/// token, or — before anything has been generated — the prompt's last
/// token. Conditioning the first step on a hardcoded id 0 instead was a
/// correctness bug that invalidated first-token generation quality.
pub fn next_conditioning_token(generated: &[usize], prompt_tail: usize) -> usize {
    generated.last().copied().unwrap_or(prompt_tail)
}

/// Builder for [`DecodeSession`] — the one construction surface (replacing
/// the old `with_budget` / `deferred` / `deferred_positional` /
/// `with_budget_positional` constructor sprawl).
///
/// Defaults: cache budget `prompt + seq_len` rows, immediate full replay,
/// classic (prompt-scaled) locality.
pub struct SessionBuilder<'a, 'p> {
    cluster: &'a Cluster,
    prompt: &'p [usize],
    s_max: Option<usize>,
    deferred: bool,
    positional: bool,
    split_weights: Option<Vec<f64>>,
}

impl<'a, 'p> SessionBuilder<'a, 'p> {
    /// Explicit per-slot cache budget: the session allocates `s_max` KV
    /// rows and can generate `s_max - prompt.len()` tokens. Continuous-
    /// batching slots size this to prompt + decode budget so KV-pressure
    /// admission ([`crate::kv::pool::KvPool`]) sees the true footprint.
    pub fn budget(mut self, s_max: usize) -> Self {
        self.s_max = Some(s_max);
        self
    }

    /// Defer the prompt replay: the cache is allocated but no rows are
    /// written until [`DecodeSession::replay_range`] (or an attach/import)
    /// delivers them. [`DecodeSession::step`] refuses to run until the
    /// whole prompt is covered.
    pub fn deferred(mut self) -> Self {
        self.deferred = true;
        self
    }

    /// Positional-locality mode — the prefix-cache serving path (see the
    /// field doc on [`DecodeSession`]).
    pub fn positional(mut self) -> Self {
        self.positional = true;
        self
    }

    /// Profile-weighted split override (heterogeneous serving, see the
    /// field doc on [`DecodeSession`]). Ignored unless the weights are
    /// positive and match the cluster's device count; classic mode only —
    /// positional locality keeps the even partition that makes rows
    /// shareable.
    pub fn split_weights(mut self, weights: Vec<f64>) -> Self {
        self.split_weights = Some(weights);
        self
    }

    pub fn build(self) -> Result<DecodeSession<'a>> {
        let s_max = self
            .s_max
            .unwrap_or(self.prompt.len() + self.cluster.artifact.meta.seq_len);
        let mut sess = DecodeSession::alloc(self.cluster, self.prompt, s_max)?;
        sess.positional = self.positional;
        let n = self.cluster.partition.n_devices();
        sess.split_weights = self
            .split_weights
            .filter(|w| w.len() == n && w.iter().all(|&x| x > 0.0) && !self.positional);
        if self.deferred {
            sess.pending_prompt = self.prompt.to_vec();
        } else {
            sess.fill_from_prompt(self.prompt)?;
        }
        Ok(sess)
    }
}

impl<'a> DecodeSession<'a> {
    /// Start building a session. Decoder artifacts only; accepts any
    /// prompt of 1..=seq_len tokens (variable-length serving).
    pub fn builder<'p>(cluster: &'a Cluster, prompt: &'p [usize]) -> SessionBuilder<'a, 'p> {
        SessionBuilder {
            cluster,
            prompt,
            s_max: None,
            deferred: false,
            positional: false,
            split_weights: None,
        }
    }

    /// Seed the cache from the prompt token ids with the default budget —
    /// shorthand for `builder(cluster, prompt).build()`.
    pub fn new(cluster: &'a Cluster, prompt: &[usize]) -> Result<DecodeSession<'a>> {
        Self::builder(cluster, prompt).build()
    }

    /// Validation + cache allocation shared by every builder path. The
    /// returned session holds zero replayed rows.
    fn alloc(cluster: &'a Cluster, prompt: &[usize], s_max: usize) -> Result<DecodeSession<'a>> {
        let meta = &cluster.artifact.meta;
        if !meta.causal {
            bail!("decode sessions require a decoder (causal) artifact");
        }
        if prompt.is_empty() {
            // an empty prompt has no tail token to condition on; falling
            // back to token id 0 would silently decode from a fabricated
            // context (the same bug class as the token-0 conditioning fix)
            bail!("decode sessions require a non-empty prompt");
        }
        if prompt.len() > meta.seq_len {
            bail!(
                "prompt has {} tokens; the artifact supports at most {} (learned positions)",
                prompt.len(),
                meta.seq_len
            );
        }
        if s_max < prompt.len() {
            bail!("cache budget {s_max} cannot hold the {}-token prompt", prompt.len());
        }
        let hh = meta.n_heads;
        let dh = meta.d_model / hh;
        Ok(DecodeSession {
            cluster,
            k_cache: (0..meta.n_layers).map(|_| Tensor::zeros(&[hh, s_max, dh])).collect(),
            v_cache: (0..meta.n_layers).map(|_| Tensor::zeros(&[hh, s_max, dh])).collect(),
            attached: Vec::new(),
            attached_hi: 0,
            len: 0,
            s_max,
            prompt_len: prompt.len(),
            generated: Vec::new(),
            prompt_tail: *prompt.last().expect("prompt checked non-empty"),
            pending_prompt: Vec::new(),
            positional: false,
            split_weights: None,
        })
    }

    /// The contiguous range of absolute positions whose rows the tail
    /// device holds in full precision. Classic mode scales the cluster's
    /// token partition to this prompt's length; positional mode pins the
    /// tail device's share of the artifact's FULL window (`seq_len / N`
    /// plus the remainder), so the answer for any position is the same in
    /// every session — the property that makes block rows shareable.
    /// Positional locality assumes the default even partition; a
    /// heterogeneous `--token-split` affects only which rows are exact,
    /// never correctness, and the accounting stays self-consistent.
    fn local_range(&self) -> (usize, usize) {
        let n = self.cluster.partition.n_devices();
        if self.positional {
            let seq = self.cluster.artifact.meta.seq_len.max(1);
            let local = seq / n + seq % n;
            (seq - local, local)
        } else {
            // an active heterogeneous plan re-weights this prompt's split;
            // builder validation guarantees the weights match n and are
            // positive, so proportional() cannot fail here
            let part = match &self.split_weights {
                Some(w) => TokenPartition::proportional(self.prompt_len, w)
                    .expect("builder-validated split weights"),
                None => prompt_partition(&self.cluster.partition, self.prompt_len),
            };
            (part.start(n - 1), part.sizes[n - 1])
        }
    }

    /// Replay the prefill from the tail device's perspective, writing KV
    /// rows: local chunk keys/values come from the full-precision stream,
    /// remote rows from the VQ-decoded stream of each layer's input.
    fn fill_from_prompt(&mut self, prompt: &[usize]) -> Result<()> {
        let meta = &self.cluster.artifact.meta;
        let t = prompt.len();
        let (local_start, local_len) = self.local_range();
        let ids = Tensor::from_vec(&[t, 1], prompt.iter().map(|&v| v as f32).collect())?;
        let mut h = self.cluster.embed(&ids)?; // [T, D] global stream
        let bias = native::causal_bias(t);
        for li in 0..meta.n_layers {
            let blk = &self.cluster.native_blocks[li];
            // the tail device sees: local rows exact, remote rows quantized
            let xhat = self.cluster.artifact.codebooks[li].roundtrip(&h)?;
            let mut mixed = xhat.clone();
            for g in local_start..(local_start + local_len).min(t) {
                let src = h.row(g).to_vec();
                mixed.row_mut(g).copy_from_slice(&src);
            }
            self.write_kv_rows(li, &mixed, blk, meta.n_heads)?;
            // advance the *global* stream exactly (all devices in lockstep);
            // the decoder's own stream is what decode steps extend
            h = native::baseline_block(&h, Some(&bias), blk, meta.n_heads)?;
        }
        self.len = t;
        Ok(())
    }

    fn write_kv_rows(&mut self, li: usize, x: &Tensor, blk: &BlockWeights, hh: usize) -> Result<()> {
        self.write_kv_rows_at(li, x, blk, hh, 0)
    }

    /// Write the mixed-precision K/V rows of `x` into cache positions
    /// `[row0, row0 + x.rows)` — `row0 > 0` is the chunked-replay path.
    fn write_kv_rows_at(
        &mut self,
        li: usize,
        x: &Tensor,
        blk: &BlockWeights,
        hh: usize,
        row0: usize,
    ) -> Result<()> {
        let xn = crate::tensor::layer_norm(x, &blk.ln1_g, &blk.ln1_b, 1e-5);
        let mut k = crate::tensor::matmul(&xn, &blk.wk)?;
        crate::tensor::add_bias(&mut k, &blk.bk);
        let mut v = crate::tensor::matmul(&xn, &blk.wv)?;
        crate::tensor::add_bias(&mut v, &blk.bv);
        let (rows, d) = k.dims2()?;
        let dh = d / hh;
        for i in 0..rows {
            for head in 0..hh {
                for j in 0..dh {
                    let kt = &mut self.k_cache[li];
                    kt.data[(head * self.s_max + row0 + i) * dh + j] = k.row(i)[head * dh + j];
                    let vt = &mut self.v_cache[li];
                    vt.data[(head * self.s_max + row0 + i) * dh + j] = v.row(i)[head * dh + j];
                }
            }
        }
        Ok(())
    }

    /// Incrementally replay prompt rows `[lo, hi)` into the mixed cache —
    /// the live half of a scheduler `PrefillChunk` event. Chunks must
    /// arrive contiguously: `lo` equals the rows already replayed.
    ///
    /// Implementation is recompute-style: the full-precision prefix
    /// `[0, hi)` is re-derived from the retained prompt ids through the
    /// very same `embed` + [`native::baseline_block`] path the one-shot
    /// replay uses, and only the new rows `[lo, hi)` are written. Because
    /// the stream is causal, rows `[0, hi)` of the prefix pass are
    /// bit-identical to the same rows of the full pass — so chunked replay
    /// reproduces the one-shot cache exactly and generations are
    /// independent of the chunking schedule. The trade is recomputed host
    /// FLOPs (like the recompute-style eviction path), not memory: no
    /// shadow full-precision K/V buffers exist, and the mixed cache the
    /// admission gate accounts for is the session's whole footprint.
    pub fn replay_range(&mut self, lo: usize, hi: usize) -> Result<()> {
        let meta = &self.cluster.artifact.meta;
        if self.pending_prompt.is_empty() {
            bail!("no deferred prompt replay in progress (session is fully prefilled)");
        }
        if lo != self.len {
            bail!("chunks must be contiguous: expected lo={}, got lo={lo}", self.len);
        }
        if hi <= lo || hi > self.prompt_len {
            bail!("bad chunk range [{lo}, {hi}) for a {}-token prompt", self.prompt_len);
        }
        let hh = meta.n_heads;
        let (local_start, local_len) = self.local_range();
        // recompute the exact stream over the visible prefix [0, hi)
        let ids = Tensor::from_vec(
            &[hi, 1],
            self.pending_prompt[..hi].iter().map(|&v| v as f32).collect(),
        )?;
        let mut h = self.cluster.embed(&ids)?;
        let bias = native::causal_bias(hi);
        for li in 0..meta.n_layers {
            let blk = &self.cluster.native_blocks[li];
            // the tail device sees: local rows exact, remote rows quantized
            let xhat = self.cluster.artifact.codebooks[li].roundtrip(&h)?;
            let d = meta.d_model;
            let mut mixed = Tensor::zeros(&[hi - lo, d]);
            for g in lo..hi {
                let local = g >= local_start && g < local_start + local_len;
                let src = if local { h.row(g) } else { xhat.row(g) };
                let src = src.to_vec();
                mixed.row_mut(g - lo).copy_from_slice(&src);
            }
            self.write_kv_rows_at(li, &mixed, blk, hh, lo)?;
            h = native::baseline_block(&h, Some(&bias), blk, hh)?;
        }
        self.len = hi;
        if hi == self.prompt_len {
            self.pending_prompt = Vec::new(); // replay complete
        }
        Ok(())
    }

    /// Generate one token greedily; returns its id. This is exactly
    /// [`step_batch`] on a batch of one — the serial anchor and the fused
    /// path share every instruction.
    pub fn step(&mut self) -> Result<usize> {
        let mut one = [self];
        let toks = step_batch(&mut one)?;
        Ok(toks[0])
    }

    /// The token id the next `step()` will embed.
    pub fn conditioning_token(&self) -> usize {
        next_conditioning_token(&self.generated, self.prompt_tail)
    }

    /// Head dimension from the artifact geometry.
    fn head_dim(&self) -> usize {
        let meta = &self.cluster.artifact.meta;
        meta.d_model / meta.n_heads
    }

    /// K row slice of `(li, head, i)`: attached arena block below the
    /// watermark, private tensor above it.
    #[inline]
    fn k_row(&self, li: usize, head: usize, i: usize) -> &[f32] {
        let dh = self.head_dim();
        if i < self.attached_hi {
            let blk = self
                .attached
                .iter()
                .find(|b| i >= b.lo && i < b.hi)
                .expect("attached blocks tile [0, attached_hi)");
            return blk.k_row(li, head, i, dh);
        }
        let off = (head * self.s_max + i) * dh;
        &self.k_cache[li].data[off..off + dh]
    }

    /// V row slice of `(li, head, i)` — see [`Self::k_row`].
    #[inline]
    fn v_row(&self, li: usize, head: usize, i: usize) -> &[f32] {
        let dh = self.head_dim();
        if i < self.attached_hi {
            let blk = self
                .attached
                .iter()
                .find(|b| i >= b.lo && i < b.hi)
                .expect("attached blocks tile [0, attached_hi)");
            return blk.v_row(li, head, i, dh);
        }
        let off = (head * self.s_max + i) * dh;
        &self.v_cache[li].data[off..off + dh]
    }

    /// Append one generated token's K/V row at position `len` (not yet
    /// advanced) in every head of layer `li`.
    fn append_kv_row(&mut self, li: usize, k_new: &[f32], v_new: &[f32]) {
        let meta = &self.cluster.artifact.meta;
        let hh = meta.n_heads;
        let dh = meta.d_model / hh;
        for head in 0..hh {
            for j in 0..dh {
                self.k_cache[li].data[(head * self.s_max + self.len) * dh + j] =
                    k_new[head * dh + j];
                self.v_cache[li].data[(head * self.s_max + self.len) * dh + j] =
                    v_new[head * dh + j];
            }
        }
    }

    fn accounting_shape(&self) -> crate::model::TransformerShape {
        let meta = &self.cluster.artifact.meta;
        crate::model::TransformerShape {
            n_layers: meta.n_layers,
            d_model: meta.d_model,
            n_heads: meta.n_heads,
            d_ff: meta.d_ff,
            seq_len: meta.seq_len,
            elem_bytes: 4,
        }
    }

    /// The Appendix-G accounting function active for this session:
    /// classic prompt-scaled locality, or the positional variant when
    /// block sharing is on (prefix differences of which are block bytes).
    fn accounting_fn(
        &self,
    ) -> fn(&crate::model::TransformerShape, usize, usize, usize, usize, usize, usize) -> usize {
        if self.positional {
            crate::model::kv_cache_bytes_astra_positional
        } else {
            crate::model::kv_cache_bytes_astra_live
        }
    }

    /// Appendix G memory accounting for the cache's *current* occupancy:
    /// mixed-precision prompt rows (only those already replayed, so a
    /// deferred session's footprint grows chunk by chunk) plus
    /// full-precision generated rows.
    pub fn cache_bytes_mixed(&self) -> usize {
        let meta = &self.cluster.artifact.meta;
        self.accounting_fn()(
            &self.accounting_shape(),
            self.len.min(self.prompt_len),
            self.len.saturating_sub(self.prompt_len),
            4,
            self.cluster.partition.n_devices(),
            meta.groups,
            meta.codebook_size,
        )
    }

    /// Appendix G accounting at the full `s_max` budget — what this slot
    /// will hold once its decode budget is exhausted (the admission gate's
    /// per-slot ceiling).
    pub fn cache_bytes_budget(&self) -> usize {
        let meta = &self.cluster.artifact.meta;
        self.accounting_fn()(
            &self.accounting_shape(),
            self.prompt_len,
            self.s_max - self.prompt_len,
            4,
            self.cluster.partition.n_devices(),
            meta.groups,
            meta.codebook_size,
        )
    }

    /// Bytes of the first `tokens` prompt rows under this session's
    /// accounting — what a shared, block-covered prefix is worth. The live
    /// backend subtracts this from [`Self::cache_bytes_mixed`] when the
    /// rows are physically backed by the shared block store, so shared
    /// bytes are counted once across sessions.
    pub fn prefix_bytes(&self, tokens: usize) -> usize {
        let meta = &self.cluster.artifact.meta;
        self.accounting_fn()(
            &self.accounting_shape(),
            tokens.min(self.prompt_len),
            0,
            4,
            self.cluster.partition.n_devices(),
            meta.groups,
            meta.codebook_size,
        )
    }

    /// Copy the K/V rows of cache positions `[lo, hi)` out of every layer
    /// — the contribution of one finished KV block to the shared store.
    /// Returns one `(k_rows, v_rows)` pair per layer, each flattened
    /// `[heads x (hi - lo) x dh]`. Rows below the attached watermark are
    /// resolved through the shared arena blocks, so an attached session
    /// exports exactly what it reads.
    pub fn export_rows(&self, lo: usize, hi: usize) -> Result<Vec<(Vec<f32>, Vec<f32>)>> {
        if lo >= hi || hi > self.len {
            bail!("export_rows: bad range [{lo}, {hi}) over {} replayed rows", self.len);
        }
        let meta = &self.cluster.artifact.meta;
        let hh = meta.n_heads;
        let dh = meta.d_model / hh;
        let mut out = Vec::with_capacity(meta.n_layers);
        for li in 0..meta.n_layers {
            let mut k = Vec::with_capacity(hh * (hi - lo) * dh);
            let mut v = Vec::with_capacity(hh * (hi - lo) * dh);
            for head in 0..hh {
                for i in lo..hi {
                    k.extend_from_slice(self.k_row(li, head, i));
                    v.extend_from_slice(self.v_row(li, head, i));
                }
            }
            out.push((k, v));
        }
        Ok(out)
    }

    /// Zero-copy attach of a sealed arena block covering `[rows.lo,
    /// rows.hi)` — the arena-backed replacement for [`Self::import_rows`]:
    /// the attach is an `Arc` clone, and decode reads the shared rows in
    /// place. Blocks must arrive contiguously, before any replayed or
    /// imported rows, and (like imports) only make sense in positional
    /// mode where rows are a pure function of the token-id prefix.
    pub fn attach_block(&mut self, rows: BlockRef) -> Result<()> {
        let meta = &self.cluster.artifact.meta;
        let (lo, hi) = (rows.lo, rows.hi);
        if lo != self.len || lo != self.attached_hi {
            bail!(
                "attach_block: blocks must be contiguous and precede replayed rows \
                 (attached to {}, session at {}, got lo={lo})",
                self.attached_hi,
                self.len
            );
        }
        if lo >= hi || hi > self.prompt_len {
            bail!("attach_block: bad range [{lo}, {hi}) for a {}-token prompt", self.prompt_len);
        }
        if rows.layers.len() != meta.n_layers {
            bail!(
                "attach_block: {} layers of rows for a {}-layer model",
                rows.layers.len(),
                meta.n_layers
            );
        }
        let hh = meta.n_heads;
        let dh = meta.d_model / hh;
        let want = hh * (hi - lo) * dh;
        for (li, (k, v)) in rows.layers.iter().enumerate() {
            if k.len() != want || v.len() != want {
                bail!("attach_block: layer {li} holds {} floats, expected {want}", k.len());
            }
        }
        self.attached.push(rows);
        self.attached_hi = hi;
        self.len = hi;
        if self.len == self.prompt_len {
            self.pending_prompt = Vec::new(); // fully covered: nothing left to replay
        }
        Ok(())
    }

    /// Write previously exported rows into positions `[lo, hi)` — the
    /// row-copying attach path, kept as the comparison anchor for the
    /// zero-copy [`Self::attach_block`]. Blocks must arrive contiguously
    /// (`lo` equals the rows already present), before any replay of the
    /// suffix. Because positional locality makes the rows a pure function
    /// of the token-id prefix, an import followed by suffix-only
    /// [`Self::replay_range`] is bit-identical to a full replay.
    pub fn import_rows(
        &mut self,
        lo: usize,
        hi: usize,
        rows: &[(Vec<f32>, Vec<f32>)],
    ) -> Result<()> {
        let meta = &self.cluster.artifact.meta;
        if lo != self.len {
            bail!("import_rows: blocks must be contiguous (have {} rows, got lo={lo})", self.len);
        }
        if lo >= hi || hi > self.prompt_len {
            bail!("import_rows: bad range [{lo}, {hi}) for a {}-token prompt", self.prompt_len);
        }
        if rows.len() != meta.n_layers {
            bail!("import_rows: {} layers of rows for a {}-layer model", rows.len(), meta.n_layers);
        }
        let hh = meta.n_heads;
        let dh = meta.d_model / hh;
        let want = hh * (hi - lo) * dh;
        for (li, (k, v)) in rows.iter().enumerate() {
            if k.len() != want || v.len() != want {
                bail!("import_rows: layer {li} holds {} floats, expected {want}", k.len());
            }
            let mut idx = 0usize;
            for head in 0..hh {
                for i in lo..hi {
                    for j in 0..dh {
                        self.k_cache[li].data[(head * self.s_max + i) * dh + j] = k[idx];
                        self.v_cache[li].data[(head * self.s_max + i) * dh + j] = v[idx];
                        idx += 1;
                    }
                }
            }
        }
        self.len = hi;
        if self.len == self.prompt_len {
            self.pending_prompt = Vec::new(); // fully covered: nothing left to replay
        }
        Ok(())
    }
}

/// Advance every session one greedy token through **one fused batched GEMM
/// per layer**: hidden states are gathered into `[batch, d_model]`, the
/// layer's LN/QKV/output/MLP operators run once over the batch, attention
/// is per-slot over each slot's own cache, and the new K/V rows scatter
/// back into per-slot storage. Returns the generated token ids in session
/// order.
///
/// Bit-identity with the serial path is by construction: every batched
/// operator is row-independent with a fixed inner accumulation order, and
/// the per-slot attention walks rows in the same ascending-`i` order the
/// serial kernel used, so batch row `r` computes exactly what a `[1, D]`
/// step of session `r` computes.
pub fn step_batch(sessions: &mut [&mut DecodeSession<'_>]) -> Result<Vec<usize>> {
    if sessions.is_empty() {
        return Ok(Vec::new());
    }
    let cluster: &Cluster = sessions[0].cluster;
    for s in sessions.iter() {
        if !std::ptr::eq(s.cluster, cluster) {
            bail!("step_batch: sessions span different clusters");
        }
        if s.len < s.prompt_len {
            bail!(
                "prompt replay incomplete ({} of {} rows): deliver the remaining chunks first",
                s.len,
                s.prompt_len
            );
        }
        if s.len >= s.s_max {
            bail!("cache full ({} rows)", s.s_max);
        }
    }
    let meta = &cluster.artifact.meta;
    let b = sessions.len();
    let d = meta.d_model;
    // gather: embed each slot's conditioning token at its own position
    let embed = cluster.artifact.tensor("embed")?;
    let pos = cluster.artifact.tensor("pos")?;
    let mut h = Tensor::zeros(&[b, d]);
    for r in 0..b {
        let s = &*sessions[r];
        let last_id = s.conditioning_token();
        let pos_idx = s.len.min(meta.seq_len - 1); // clamp learned pos
        for j in 0..d {
            h.row_mut(r)[j] = embed.row(last_id)[j] + pos.row(pos_idx)[j];
        }
    }
    for li in 0..meta.n_layers {
        let blk = &cluster.native_blocks[li];
        // one fused GEMM per projection across the whole batch
        let xn = crate::tensor::layer_norm(&h, &blk.ln1_g, &blk.ln1_b, 1e-5);
        let mut q = crate::tensor::matmul(&xn, &blk.wq)?;
        crate::tensor::add_bias(&mut q, &blk.bq);
        let mut k_t = crate::tensor::matmul(&xn, &blk.wk)?;
        crate::tensor::add_bias(&mut k_t, &blk.bk);
        let mut v_t = crate::tensor::matmul(&xn, &blk.wv)?;
        crate::tensor::add_bias(&mut v_t, &blk.bv);
        // per-slot attention: reads are slot-private (own cache + attached
        // arena blocks), arithmetic identical to the serial kernel
        let mut att_out = Tensor::zeros(&[b, d]);
        for r in 0..b {
            let s = &*sessions[r];
            attend_one(s, li, q.row(r), k_t.row(r), v_t.row(r), att_out.row_mut(r));
        }
        let mut h1 = crate::tensor::matmul(&att_out, &blk.wo)?;
        crate::tensor::add_bias(&mut h1, &blk.bo);
        crate::tensor::add_inplace(&mut h1, &h);
        // MLP, fused across the batch
        let xn2 = crate::tensor::layer_norm(&h1, &blk.ln2_g, &blk.ln2_b, 1e-5);
        let mut m = crate::tensor::matmul(&xn2, &blk.w1)?;
        crate::tensor::add_bias(&mut m, &blk.b1);
        crate::tensor::gelu(&mut m);
        let mut m2 = crate::tensor::matmul(&m, &blk.w2)?;
        crate::tensor::add_bias(&mut m2, &blk.b2);
        crate::tensor::add_inplace(&mut m2, &h1);
        // scatter: append each slot's new K/V row at its own `len`
        for r in 0..b {
            let k_new = k_t.row(r).to_vec();
            let v_new = v_t.row(r).to_vec();
            sessions[r].append_kv_row(li, &k_new, &v_new);
        }
        h = m2;
    }
    let logits = native::lm_head(
        &h,
        &cluster.artifact.tensor("ln_f.g")?.data,
        &cluster.artifact.tensor("ln_f.b")?.data,
        cluster.artifact.tensor("head.w")?,
        &cluster.artifact.tensor("head.b")?.data,
    )?;
    let mut out = Vec::with_capacity(b);
    for r in 0..b {
        let next = logits
            .row(r)
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(i, _)| i)
            .unwrap_or(0);
        sessions[r].len += 1;
        sessions[r].generated.push(next);
        out.push(next);
    }
    Ok(out)
}

/// One slot's attention for one layer, mirroring python `decode_step_block`:
/// logits over the slot's cached rows (ascending `i`, resolved through
/// attached arena blocks below the watermark) plus the new token itself,
/// softmax, weighted value sum into `out[head * dh + j]`.
///
/// The old serial kernel masked invalid rows to `-inf`; because valid rows
/// are always the contiguous prefix `[0, len)`, iterating only them is
/// bit-identical (`exp(-inf) = 0` contributed exactly `+0.0` to the sum,
/// and `max(x, -inf) = x`).
fn attend_one(
    s: &DecodeSession<'_>,
    li: usize,
    q_row: &[f32],
    k_new: &[f32],
    v_new: &[f32],
    out: &mut [f32],
) {
    let dh = s.head_dim();
    let hh = q_row.len() / dh;
    let scale = 1.0 / (dh as f32).sqrt();
    for head in 0..hh {
        let qh = &q_row[head * dh..(head + 1) * dh];
        let mut logits = Vec::with_capacity(s.len + 1);
        for i in 0..s.len {
            let krow = s.k_row(li, head, i);
            let mut acc = 0.0f32;
            for j in 0..dh {
                acc += qh[j] * krow[j];
            }
            logits.push(acc * scale);
        }
        // self
        let mut acc = 0.0f32;
        for j in 0..dh {
            acc += qh[j] * k_new[head * dh + j];
        }
        logits.push(acc * scale);
        // softmax
        let max = logits.iter().copied().fold(f32::NEG_INFINITY, f32::max);
        let mut sum = 0.0f32;
        for l in logits.iter_mut() {
            *l = (*l - max).exp();
            sum += *l;
        }
        // weighted value sum
        for j in 0..dh {
            let mut o = 0.0f32;
            for i in 0..s.len {
                o += logits[i] * s.v_row(li, head, i)[j];
            }
            o += logits[s.len] * v_new[head * dh + j];
            out[head * dh + j] = o / sum;
        }
    }
}

#[cfg(test)]
mod tests {
    use std::sync::Arc;

    use super::{next_conditioning_token, prompt_partition, step_batch, DecodeSession};
    use crate::config::RunConfig;
    use crate::coordinator::{Cluster, TokenPartition};
    use crate::kv::arena::{BlockRows, KvArena};
    use crate::model::shape::VqSetting;
    use crate::model::TransformerShape;

    #[test]
    fn first_step_conditions_on_prompt_tail_not_token_zero() {
        // regression: before the fix, the first decode step embedded token
        // id 0 (`generated.last().unwrap_or(&0)`) regardless of the prompt
        assert_eq!(next_conditioning_token(&[], 173), 173);
        assert_ne!(next_conditioning_token(&[], 173), 0);
        // after generation starts, the newest generated token wins
        assert_eq!(next_conditioning_token(&[5, 9], 173), 9);
        // degenerate tail id 0 is still honoured (only correct when the
        // prompt really ends in token 0)
        assert_eq!(next_conditioning_token(&[], 0), 0);
    }

    #[test]
    fn prompt_partition_scales_and_tail_owns_remainder() {
        // even partitions: all shares tie, so the tail-most device still
        // absorbs the remainder — the historical behavior, pinned exactly
        let full = TokenPartition::explicit(vec![4, 4, 4, 4]);
        assert_eq!(prompt_partition(&full, 16).sizes, vec![4, 4, 4, 4]);
        assert_eq!(prompt_partition(&full, 10).sizes, vec![2, 2, 2, 4]);
        assert_eq!(prompt_partition(&full, 3).sizes, vec![0, 0, 0, 3]);
        assert_eq!(prompt_partition(&full, 1).sizes, vec![0, 0, 0, 1]);
        // heterogeneous splits keep their proportions
        let het = TokenPartition::explicit(vec![8, 4, 4]);
        let p = prompt_partition(&het, 8);
        assert_eq!(p.total(), 8);
        assert!(p.sizes[0] >= p.sizes[1]);
    }

    #[test]
    fn prompt_partition_remainder_goes_to_the_fastest_device() {
        // regression (PR 10): the remainder used to go to the *tail*
        // device, which on a skewed fleet is the slowest straggler.
        // Hand-computed: shares [8,4,4] of 16 scaled to 7 tokens floor to
        // [3,1,1] (used 5), remainder 2 -> device 0 (largest share).
        let het = TokenPartition::explicit(vec![8, 4, 4]);
        assert_eq!(prompt_partition(&het, 7).sizes, vec![5, 1, 1]);
        // fastest device at the tail: floors [0,1,3] (used 4), rem 3 -> tail
        let rev = TokenPartition::explicit(vec![2, 4, 8]);
        assert_eq!(prompt_partition(&rev, 7).sizes, vec![0, 1, 6]);
        // tie between equal shares breaks toward the tail-most maximum
        let tie = TokenPartition::explicit(vec![4, 4]);
        assert_eq!(prompt_partition(&tie, 3).sizes, vec![1, 2]);
        // exact scaling still reproduces the partition
        assert_eq!(prompt_partition(&het, 16).sizes, vec![8, 4, 4]);
    }

    fn tiny_cluster() -> Cluster {
        let shape = TransformerShape {
            n_layers: 2,
            d_model: 16,
            n_heads: 2,
            d_ff: 32,
            seq_len: 16,
            elem_bytes: 4,
        };
        let config = RunConfig { n_devices: 2, ..RunConfig::default() };
        Cluster::synthetic_decoder(&shape, 32, VqSetting::new(2, 8), config, 11).unwrap()
    }

    #[test]
    fn empty_prompt_is_rejected() {
        // regression: `prompt_tail` used to fall back to token 0 via
        // `unwrap_or(0)`, so an empty prompt silently decoded from a
        // fabricated context instead of erroring
        let cluster = tiny_cluster();
        let err = DecodeSession::new(&cluster, &[]).err().expect("empty prompt must fail");
        assert!(err.to_string().contains("non-empty"), "{err}");
        assert!(DecodeSession::builder(&cluster, &[]).budget(8).build().is_err());
        // one token is the minimum viable prompt
        assert!(DecodeSession::new(&cluster, &[3]).is_ok());
    }

    #[test]
    fn variable_length_prompts_generate_deterministically() {
        let cluster = tiny_cluster();
        let vocab = cluster.artifact.meta.vocab_size;
        for plen in [1usize, 5, 9, 16] {
            let prompt: Vec<usize> = (0..plen).map(|i| (i * 5 + 1) % vocab).collect();
            let mut sess = DecodeSession::new(&cluster, &prompt).unwrap();
            assert_eq!(sess.len, plen);
            assert_eq!(sess.prompt_len, plen);
            let toks: Vec<usize> = (0..4).map(|_| sess.step().unwrap()).collect();
            assert!(toks.iter().all(|&t| t < vocab));
            assert_eq!(sess.len, plen + 4);
            // greedy decode reproduces bit-for-bit from a fresh session
            let mut again = DecodeSession::new(&cluster, &prompt).unwrap();
            let toks2: Vec<usize> = (0..4).map(|_| again.step().unwrap()).collect();
            assert_eq!(toks, toks2, "plen={plen}");
        }
        // prompts longer than the learned positions are rejected
        assert!(DecodeSession::new(&cluster, &[1usize; 17]).is_err());
    }

    #[test]
    fn chunked_replay_matches_one_shot_bit_for_bit() {
        // the chunked-prefill correctness anchor: delivering the prompt in
        // arbitrary contiguous chunks must build the exact cache the
        // one-shot replay builds (causality: a chunk advanced over the
        // exact K/V of its predecessors sees what the full pass saw)
        let cluster = tiny_cluster();
        let vocab = cluster.artifact.meta.vocab_size;
        let prompt: Vec<usize> = (0..13).map(|i| (i * 7 + 2) % vocab).collect();
        let mut full = DecodeSession::builder(&cluster, &prompt).budget(13 + 4).build().unwrap();
        let mut chunked =
            DecodeSession::builder(&cluster, &prompt).deferred().budget(13 + 4).build().unwrap();
        // decode refuses to run mid-replay
        assert!(chunked.step().is_err());
        assert_eq!(chunked.cache_bytes_mixed(), 0);
        for (lo, hi) in [(0usize, 5usize), (5, 6), (6, 13)] {
            chunked.replay_range(lo, hi).unwrap();
            assert_eq!(chunked.len, hi);
        }
        assert_eq!(chunked.cache_bytes_mixed(), full.cache_bytes_mixed());
        for li in 0..cluster.artifact.meta.n_layers {
            assert_eq!(chunked.k_cache[li].data, full.k_cache[li].data, "K layer {li}");
            assert_eq!(chunked.v_cache[li].data, full.v_cache[li].data, "V layer {li}");
        }
        let a: Vec<usize> = (0..4).map(|_| full.step().unwrap()).collect();
        let b: Vec<usize> = (0..4).map(|_| chunked.step().unwrap()).collect();
        assert_eq!(a, b, "incremental replay diverged from one-shot replay");
    }

    #[test]
    fn replay_range_enforces_contiguity_and_bounds() {
        let cluster = tiny_cluster();
        let vocab = cluster.artifact.meta.vocab_size;
        let prompt = [1usize, 2, 3, 4, 5, 6];
        let mut sess =
            DecodeSession::builder(&cluster, &prompt).deferred().budget(12).build().unwrap();
        assert!(sess.replay_range(2, 4).is_err(), "must start at 0");
        assert!(sess.replay_range(0, 0).is_err(), "empty chunk");
        assert!(sess.replay_range(0, 7).is_err(), "past the prompt");
        sess.replay_range(0, 3).unwrap();
        assert!(sess.replay_range(0, 4).is_err(), "must resume at row 3");
        // partial occupancy: fewer bytes than a fully replayed session
        let full = DecodeSession::builder(&cluster, &prompt).budget(12).build().unwrap();
        assert!(sess.cache_bytes_mixed() < full.cache_bytes_mixed());
        sess.replay_range(3, 6).unwrap();
        // replay complete: buffers freed, further chunks rejected
        assert!(sess.replay_range(6, 7).is_err());
        assert!(sess.step().unwrap() < vocab);
    }

    #[test]
    fn positional_block_import_plus_suffix_replay_is_bit_identical_to_full_replay() {
        // the prefix-cache correctness anchor: export block-aligned rows
        // from a donor, import them into a fresh session, replay only the
        // uncovered suffix — the raw cache floats must equal a full
        // positional replay, and greedy decode must be identical. This is
        // what makes attaching to shared blocks semantically free.
        let cluster = tiny_cluster();
        let vocab = cluster.artifact.meta.vocab_size;
        let prompt: Vec<usize> = (0..13).map(|i| (i * 7 + 2) % vocab).collect();
        let block = 4usize; // 3 full blocks cover [0, 12); token 12 is the suffix
        let mut donor =
            DecodeSession::builder(&cluster, &prompt).positional().budget(13 + 4).build().unwrap();
        let mut attached = DecodeSession::builder(&cluster, &prompt)
            .deferred()
            .positional()
            .budget(13 + 4)
            .build()
            .unwrap();
        assert!(attached.step().is_err(), "no decode before the prompt is complete");
        for k in 0..3 {
            let rows = donor.export_rows(k * block, (k + 1) * block).unwrap();
            attached.import_rows(k * block, (k + 1) * block, &rows).unwrap();
        }
        assert_eq!(attached.len, 12);
        // covered prefix is cheaper than the full prompt under accounting
        assert!(attached.cache_bytes_mixed() < donor.cache_bytes_mixed());
        assert_eq!(attached.prefix_bytes(12), attached.cache_bytes_mixed());
        attached.replay_range(12, 13).unwrap();
        assert_eq!(attached.cache_bytes_mixed(), donor.cache_bytes_mixed());
        for li in 0..cluster.artifact.meta.n_layers {
            assert_eq!(attached.k_cache[li].data, donor.k_cache[li].data, "K layer {li}");
            assert_eq!(attached.v_cache[li].data, donor.v_cache[li].data, "V layer {li}");
        }
        let a: Vec<usize> = (0..4).map(|_| donor.step().unwrap()).collect();
        let b: Vec<usize> = (0..4).map(|_| attached.step().unwrap()).collect();
        assert_eq!(a, b, "prefix attach changed greedy decode");
    }

    #[test]
    fn positional_rows_are_prefix_pure_across_prompt_lengths() {
        // the reason positional mode exists: the same leading token ids
        // must produce the same K/V rows whatever the prompt's total
        // length. Classic (prompt-scaled) locality does NOT have this
        // property, which is why blocks are only shared in positional mode.
        let cluster = tiny_cluster();
        let vocab = cluster.artifact.meta.vocab_size;
        let long: Vec<usize> = (0..12).map(|i| (i * 5 + 3) % vocab).collect();
        let short = long[..8].to_vec();
        let a = DecodeSession::builder(&cluster, &long).positional().budget(16).build().unwrap();
        let b = DecodeSession::builder(&cluster, &short).positional().budget(16).build().unwrap();
        let ra = a.export_rows(0, 8).unwrap();
        let rb = b.export_rows(0, 8).unwrap();
        assert_eq!(ra, rb, "shared 8-token prefix must yield identical rows");
        // accounting agrees with the positional Appendix-G function
        let meta = &cluster.artifact.meta;
        let want = crate::model::kv_cache_bytes_astra_positional(
            &a.accounting_shape(),
            12,
            0,
            4,
            cluster.partition.n_devices(),
            meta.groups,
            meta.codebook_size,
        );
        assert_eq!(a.cache_bytes_mixed(), want);
    }

    #[test]
    fn import_rows_enforces_contiguity_shape_and_bounds() {
        let cluster = tiny_cluster();
        let prompt = [1usize, 2, 3, 4, 5, 6, 7, 8];
        let donor =
            DecodeSession::builder(&cluster, &prompt).positional().budget(12).build().unwrap();
        let rows = donor.export_rows(0, 4).unwrap();
        let mut sess = DecodeSession::builder(&cluster, &prompt)
            .deferred()
            .positional()
            .budget(12)
            .build()
            .unwrap();
        assert!(sess.import_rows(4, 8, &donor.export_rows(4, 8).unwrap()).is_err(), "gap");
        assert!(sess.import_rows(0, 0, &rows).is_err(), "empty");
        assert!(sess.import_rows(0, 9, &rows).is_err(), "past the prompt");
        assert!(sess.import_rows(0, 3, &rows).is_err(), "row-count mismatch");
        sess.import_rows(0, 4, &rows).unwrap();
        assert_eq!(sess.len, 4);
        // replay continues from the imported edge only
        assert!(sess.replay_range(0, 4).is_err());
        sess.replay_range(4, 8).unwrap();
        assert!(sess.step().is_ok());
        // export refuses rows that were never written
        assert!(donor.export_rows(7, 8).is_ok());
        assert!(donor.export_rows(8, 9).is_err(), "past replayed rows");
    }

    #[test]
    fn cache_budget_caps_generation() {
        let cluster = tiny_cluster();
        let prompt = [1usize, 2, 3, 4, 5];
        // budget must at least hold the prompt
        assert!(DecodeSession::builder(&cluster, &prompt).budget(4).build().is_err());
        let mut sess = DecodeSession::builder(&cluster, &prompt).budget(7).build().unwrap();
        sess.step().unwrap();
        sess.step().unwrap();
        let err = sess.step().expect_err("cache must be full at s_max");
        assert!(err.to_string().contains("cache full"), "{err}");
        // budget accounting: current occupancy grows toward the ceiling
        assert!(sess.cache_bytes_mixed() <= sess.cache_bytes_budget());
        let fresh = DecodeSession::builder(&cluster, &prompt).budget(7).build().unwrap();
        assert!(fresh.cache_bytes_mixed() < sess.cache_bytes_mixed());
    }

    #[test]
    fn batched_decode_matches_serial_decode_bit_for_bit() {
        // the tentpole's correctness anchor: for every batch size 1..=8,
        // over mixed prompt lengths, with one arena-attached (prefix-hit)
        // slot in the mix and a mid-batch eviction, the fused batched step
        // must produce the same tokens AND the same raw cache floats as
        // stepping each session alone.
        let cluster = tiny_cluster();
        let meta = &cluster.artifact.meta;
        let vocab = meta.vocab_size;
        let (hh, dh) = (meta.n_heads, meta.d_model / meta.n_heads);
        for b in 1usize..=8 {
            let prompts: Vec<Vec<usize>> = (0..b)
                .map(|r| {
                    let plen = 1 + (r * 3 + b) % 12;
                    (0..plen).map(|i| (i * 7 + r * 5 + 2) % vocab).collect()
                })
                .collect();
            // `make` captures `&cluster`, so both worlds borrow one cluster
            let make = |r: usize, p: &[usize]| {
                if r == 1 {
                    // a prefix-hit slot: its whole prompt arrives as one
                    // sealed arena block from a donor session
                    let donor = DecodeSession::builder(&cluster, p)
                        .positional()
                        .budget(p.len() + 6)
                        .build()
                        .unwrap();
                    let rows =
                        BlockRows::new(0, p.len(), donor.export_rows(0, p.len()).unwrap(), hh, dh)
                            .unwrap();
                    let mut s = DecodeSession::builder(&cluster, p)
                        .deferred()
                        .positional()
                        .budget(p.len() + 6)
                        .build()
                        .unwrap();
                    s.attach_block(Arc::new(rows)).unwrap();
                    s
                } else {
                    DecodeSession::builder(&cluster, p).budget(p.len() + 6).build().unwrap()
                }
            };
            let mut serial: Vec<DecodeSession<'_>> =
                prompts.iter().enumerate().map(|(r, p)| make(r, p)).collect();
            let mut batched: Vec<DecodeSession<'_>> =
                prompts.iter().enumerate().map(|(r, p)| make(r, p)).collect();
            for round in 0..3 {
                let serial_toks: Vec<usize> =
                    serial.iter_mut().map(|s| s.step().unwrap()).collect();
                let mut refs: Vec<&mut DecodeSession<'_>> = batched.iter_mut().collect();
                let batched_toks = step_batch(&mut refs).unwrap();
                assert_eq!(serial_toks, batched_toks, "b={b} round={round}");
                if round == 0 && b > 2 {
                    // mid-batch eviction: a middle slot leaves both worlds
                    serial.remove(b / 2);
                    batched.remove(b / 2);
                }
            }
            for (s, bt) in serial.iter().zip(batched.iter()) {
                assert_eq!(s.len, bt.len, "b={b}");
                assert_eq!(s.generated, bt.generated, "b={b}");
                assert_eq!(
                    s.export_rows(0, s.len).unwrap(),
                    bt.export_rows(0, bt.len).unwrap(),
                    "raw cache floats diverged at b={b}"
                );
            }
        }
    }

    #[test]
    fn arena_attach_is_bit_identical_to_row_copy_import() {
        // zero-copy attach vs the old copying import: same tokens, same
        // raw cache floats (export resolves attached rows through the
        // arena, imported rows through the private tensor)
        let cluster = tiny_cluster();
        let meta = &cluster.artifact.meta;
        let vocab = meta.vocab_size;
        let (hh, dh) = (meta.n_heads, meta.d_model / meta.n_heads);
        let prompt: Vec<usize> = (0..13).map(|i| (i * 7 + 2) % vocab).collect();
        let block = 4usize;
        let donor =
            DecodeSession::builder(&cluster, &prompt).positional().budget(13 + 4).build().unwrap();
        let mut imported = DecodeSession::builder(&cluster, &prompt)
            .deferred()
            .positional()
            .budget(13 + 4)
            .build()
            .unwrap();
        let mut attached = DecodeSession::builder(&cluster, &prompt)
            .deferred()
            .positional()
            .budget(13 + 4)
            .build()
            .unwrap();
        for k in 0..3 {
            let rows = donor.export_rows(k * block, (k + 1) * block).unwrap();
            imported.import_rows(k * block, (k + 1) * block, &rows).unwrap();
            let sealed = BlockRows::new(k * block, (k + 1) * block, rows, hh, dh).unwrap();
            attached.attach_block(Arc::new(sealed)).unwrap();
        }
        attached.replay_range(12, 13).unwrap();
        imported.replay_range(12, 13).unwrap();
        assert_eq!(attached.cache_bytes_mixed(), imported.cache_bytes_mixed());
        let a: Vec<usize> = (0..4).map(|_| attached.step().unwrap()).collect();
        let i: Vec<usize> = (0..4).map(|_| imported.step().unwrap()).collect();
        assert_eq!(a, i, "attach diverged from import");
        assert_eq!(
            attached.export_rows(0, attached.len).unwrap(),
            imported.export_rows(0, imported.len).unwrap(),
            "raw cache floats diverged between attach and import"
        );
    }

    #[test]
    fn attached_block_survives_creator_drop() {
        // aliasing: the arena entry is refcounted, so dropping the creator
        // session — and even evicting the block from the arena — must not
        // invalidate sessions that already attached it
        let cluster = tiny_cluster();
        let meta = &cluster.artifact.meta;
        let vocab = meta.vocab_size;
        let (hh, dh) = (meta.n_heads, meta.d_model / meta.n_heads);
        let prompt: Vec<usize> = (0..12).map(|i| (i * 5 + 3) % vocab).collect();
        let mut arena = KvArena::new();
        {
            let donor = DecodeSession::builder(&cluster, &prompt)
                .positional()
                .budget(16)
                .build()
                .unwrap();
            for k in 0..3u64 {
                let (lo, hi) = (k as usize * 4, k as usize * 4 + 4);
                let rows =
                    BlockRows::new(lo, hi, donor.export_rows(lo, hi).unwrap(), hh, dh).unwrap();
                arena.insert(k, 100, rows);
            }
        } // donor dropped here
        let mut attached = DecodeSession::builder(&cluster, &prompt)
            .deferred()
            .positional()
            .budget(16)
            .build()
            .unwrap();
        for k in 0..3u64 {
            attached.attach_block(arena.attach(k).unwrap()).unwrap();
        }
        // even the arena's own references can go away mid-flight
        for k in 0..3u64 {
            arena.remove(k);
        }
        let mut control =
            DecodeSession::builder(&cluster, &prompt).positional().budget(16).build().unwrap();
        let a: Vec<usize> = (0..4).map(|_| attached.step().unwrap()).collect();
        let c: Vec<usize> = (0..4).map(|_| control.step().unwrap()).collect();
        assert_eq!(a, c, "attached session diverged after creator drop");
        assert_eq!(
            attached.export_rows(0, attached.len).unwrap(),
            control.export_rows(0, control.len).unwrap()
        );
    }

    #[test]
    fn attach_block_enforces_contiguity_and_geometry() {
        let cluster = tiny_cluster();
        let meta = &cluster.artifact.meta;
        let vocab = meta.vocab_size;
        let (hh, dh) = (meta.n_heads, meta.d_model / meta.n_heads);
        let prompt: Vec<usize> = (0..8).map(|i| (i * 5 + 3) % vocab).collect();
        let donor =
            DecodeSession::builder(&cluster, &prompt).positional().budget(12).build().unwrap();
        let seal = |lo: usize, hi: usize| {
            Arc::new(BlockRows::new(lo, hi, donor.export_rows(lo, hi).unwrap(), hh, dh).unwrap())
        };
        let mut sess = DecodeSession::builder(&cluster, &prompt)
            .deferred()
            .positional()
            .budget(12)
            .build()
            .unwrap();
        assert!(sess.attach_block(seal(4, 8)).is_err(), "gap");
        // wrong layer count is rejected
        let skinny = Arc::new(BlockRows {
            lo: 0,
            hi: 4,
            layers: vec![(vec![0.0; hh * 4 * dh], vec![0.0; hh * 4 * dh])],
        });
        assert!(sess.attach_block(skinny).is_err(), "layer count");
        sess.attach_block(seal(0, 4)).unwrap();
        assert_eq!(sess.len, 4);
        // after a replayed row, further attaches are refused (blocks must
        // precede private rows so reads below the watermark stay arena-only)
        sess.replay_range(4, 6).unwrap();
        assert!(sess.attach_block(seal(6, 8)).is_err(), "attach after replay");
        sess.replay_range(6, 8).unwrap();
        assert!(sess.step().is_ok());
    }
}
