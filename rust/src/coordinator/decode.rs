//! Autoregressive decode with a mixed-precision KV cache (paper §3.1/§5 +
//! Appendix G): after the sequence-parallel prefill, generation proceeds
//! on the device owning the sequence tail. That device's cache holds its
//! *local* prefill tokens in full precision and the other devices' tokens
//! as dequantized VQ codes — Appendix G's memory accounting.

use anyhow::{bail, Result};

use crate::model::native::{self, BlockWeights};
use crate::tensor::Tensor;

use super::cluster::Cluster;
use super::partition::TokenPartition;

/// Per-layer KV cache on the tail device: [H, S_max, dh] with `len` valid.
pub struct DecodeSession<'a> {
    cluster: &'a Cluster,
    k_cache: Vec<Tensor>,
    v_cache: Vec<Tensor>,
    pub len: usize,
    pub s_max: usize,
    /// prompt length; rows `[0, len.min(prompt_len))` have been replayed
    /// (all of them at construction, except for [`Self::deferred`]
    /// sessions, which receive the prompt chunk by chunk through
    /// [`Self::replay_range`])
    pub prompt_len: usize,
    pub generated: Vec<usize>,
    /// last prompt token id — the first decode step conditions on this
    /// (NOT token 0; see `conditioning_token`)
    pub prompt_tail: usize,
    /// prompt ids retained for deferred (chunked) replay; drained to empty
    /// once the whole prompt has been replayed. Chunked replay recomputes
    /// the full-precision prefix from these ids each chunk (compute for
    /// memory, like the recompute-style eviction path) instead of caching
    /// exact K/V rows — the mixed cache stays the only persistent
    /// allocation, so the KV accounting the admission gate sees is the
    /// whole live footprint.
    pending_prompt: Vec<usize>,
    /// positional-locality mode (prefix-cache serving): which prompt rows
    /// are full precision depends only on a token's absolute position
    /// inside the artifact's full window — NOT on this prompt's total
    /// length — so K/V rows are a pure function of the token-id prefix and
    /// block-aligned prefixes can be copied between sessions bit for bit
    /// ([`Self::export_rows`] / [`Self::import_rows`]). Accounting uses
    /// [`crate::model::kv_cache_bytes_astra_positional`]. Off (the
    /// default) preserves the classic prompt-scaled partition exactly.
    positional: bool,
}

/// Scale the cluster's token partition down to a `t`-token prompt: each
/// device keeps its proportional share (floor), and the tail device — the
/// one that owns the sequence tail and runs decode — absorbs the
/// remainder. For `t == partition.total()` this reproduces the partition
/// exactly, so full-length prompts behave as before.
pub fn prompt_partition(full: &TokenPartition, t: usize) -> TokenPartition {
    let n = full.n_devices();
    let total = full.total().max(1);
    let mut sizes: Vec<usize> = full.sizes.iter().map(|&s| s * t / total).collect();
    let used: usize = sizes.iter().sum();
    sizes[n - 1] += t - used;
    TokenPartition::explicit(sizes)
}

/// The token id the next decode step embeds: the most recently generated
/// token, or — before anything has been generated — the prompt's last
/// token. Conditioning the first step on a hardcoded id 0 instead was a
/// correctness bug that invalidated first-token generation quality.
pub fn next_conditioning_token(generated: &[usize], prompt_tail: usize) -> usize {
    generated.last().copied().unwrap_or(prompt_tail)
}

impl<'a> DecodeSession<'a> {
    /// Seed the cache from the prompt token ids, replaying the tail
    /// device's view of the prefill (local rows full precision, remote
    /// rows dequantized). Decoder artifacts only. Accepts any prompt of
    /// 1..=seq_len tokens (variable-length serving); the default cache
    /// budget leaves room for `seq_len` generated tokens.
    pub fn new(cluster: &'a Cluster, prompt: &[usize]) -> Result<DecodeSession<'a>> {
        let s_max = prompt.len() + cluster.artifact.meta.seq_len;
        Self::with_budget(cluster, prompt, s_max)
    }

    /// `new` with an explicit per-slot cache budget: the session allocates
    /// `s_max` KV rows and can generate `s_max - prompt.len()` tokens.
    /// Continuous-batching slots size this to prompt + decode budget so
    /// KV-pressure admission (`crate::kv::pool::KvPool`) sees the true
    /// per-slot footprint.
    pub fn with_budget(
        cluster: &'a Cluster,
        prompt: &[usize],
        s_max: usize,
    ) -> Result<DecodeSession<'a>> {
        let mut sess = Self::alloc(cluster, prompt, s_max)?;
        sess.fill_from_prompt(prompt)?;
        Ok(sess)
    }

    /// `with_budget` with the prompt replay *deferred*: the cache is
    /// allocated but no rows are written until [`Self::replay_range`]
    /// delivers them chunk by chunk (the live half of the scheduler's
    /// chunked prefill). [`Self::step`] refuses to run until the whole
    /// prompt has been replayed.
    pub fn deferred(
        cluster: &'a Cluster,
        prompt: &[usize],
        s_max: usize,
    ) -> Result<DecodeSession<'a>> {
        let mut sess = Self::alloc(cluster, prompt, s_max)?;
        sess.pending_prompt = prompt.to_vec();
        Ok(sess)
    }

    /// [`Self::deferred`] in positional-locality mode — the prefix-cache
    /// serving path: rows may arrive as imported shared blocks
    /// ([`Self::import_rows`]) followed by [`Self::replay_range`] chunks
    /// of the uncovered suffix.
    pub fn deferred_positional(
        cluster: &'a Cluster,
        prompt: &[usize],
        s_max: usize,
    ) -> Result<DecodeSession<'a>> {
        let mut sess = Self::deferred(cluster, prompt, s_max)?;
        sess.positional = true;
        Ok(sess)
    }

    /// [`Self::with_budget`] in positional-locality mode (full replay at
    /// construction) — the donor side of block sharing, and the reference
    /// a prefix-attached session must match bit for bit.
    pub fn with_budget_positional(
        cluster: &'a Cluster,
        prompt: &[usize],
        s_max: usize,
    ) -> Result<DecodeSession<'a>> {
        let mut sess = Self::alloc(cluster, prompt, s_max)?;
        sess.positional = true;
        sess.fill_from_prompt(prompt)?;
        Ok(sess)
    }

    /// Validation + cache allocation shared by the immediate and deferred
    /// constructors. The returned session holds zero replayed rows.
    fn alloc(cluster: &'a Cluster, prompt: &[usize], s_max: usize) -> Result<DecodeSession<'a>> {
        let meta = &cluster.artifact.meta;
        if !meta.causal {
            bail!("decode sessions require a decoder (causal) artifact");
        }
        if prompt.is_empty() {
            // an empty prompt has no tail token to condition on; falling
            // back to token id 0 would silently decode from a fabricated
            // context (the same bug class as the token-0 conditioning fix)
            bail!("decode sessions require a non-empty prompt");
        }
        if prompt.len() > meta.seq_len {
            bail!(
                "prompt has {} tokens; the artifact supports at most {} (learned positions)",
                prompt.len(),
                meta.seq_len
            );
        }
        if s_max < prompt.len() {
            bail!("cache budget {s_max} cannot hold the {}-token prompt", prompt.len());
        }
        let hh = meta.n_heads;
        let dh = meta.d_model / hh;
        Ok(DecodeSession {
            cluster,
            k_cache: (0..meta.n_layers).map(|_| Tensor::zeros(&[hh, s_max, dh])).collect(),
            v_cache: (0..meta.n_layers).map(|_| Tensor::zeros(&[hh, s_max, dh])).collect(),
            len: 0,
            s_max,
            prompt_len: prompt.len(),
            generated: Vec::new(),
            prompt_tail: *prompt.last().expect("prompt checked non-empty"),
            pending_prompt: Vec::new(),
            positional: false,
        })
    }

    /// The contiguous range of absolute positions whose rows the tail
    /// device holds in full precision. Classic mode scales the cluster's
    /// token partition to this prompt's length; positional mode pins the
    /// tail device's share of the artifact's FULL window (`seq_len / N`
    /// plus the remainder), so the answer for any position is the same in
    /// every session — the property that makes block rows shareable.
    /// Positional locality assumes the default even partition; a
    /// heterogeneous `--token-split` affects only which rows are exact,
    /// never correctness, and the accounting stays self-consistent.
    fn local_range(&self) -> (usize, usize) {
        let n = self.cluster.partition.n_devices();
        if self.positional {
            let seq = self.cluster.artifact.meta.seq_len.max(1);
            let local = seq / n + seq % n;
            (seq - local, local)
        } else {
            let part = prompt_partition(&self.cluster.partition, self.prompt_len);
            (part.start(n - 1), part.sizes[n - 1])
        }
    }

    /// Replay the prefill from the tail device's perspective, writing KV
    /// rows: local chunk keys/values come from the full-precision stream,
    /// remote rows from the VQ-decoded stream of each layer's input.
    fn fill_from_prompt(&mut self, prompt: &[usize]) -> Result<()> {
        let meta = &self.cluster.artifact.meta;
        let t = prompt.len();
        let (local_start, local_len) = self.local_range();
        let ids = Tensor::from_vec(&[t, 1], prompt.iter().map(|&v| v as f32).collect())?;
        let mut h = self.cluster.embed(&ids)?; // [T, D] global stream
        let bias = native::causal_bias(t);
        for li in 0..meta.n_layers {
            let blk = &self.cluster.native_blocks[li];
            // the tail device sees: local rows exact, remote rows quantized
            let xhat = self.cluster.artifact.codebooks[li].roundtrip(&h)?;
            let mut mixed = xhat.clone();
            for g in local_start..(local_start + local_len).min(t) {
                let src = h.row(g).to_vec();
                mixed.row_mut(g).copy_from_slice(&src);
            }
            self.write_kv_rows(li, &mixed, blk, meta.n_heads)?;
            // advance the *global* stream exactly (all devices in lockstep);
            // the decoder's own stream is what decode steps extend
            h = native::baseline_block(&h, Some(&bias), blk, meta.n_heads)?;
        }
        self.len = t;
        Ok(())
    }

    fn write_kv_rows(&mut self, li: usize, x: &Tensor, blk: &BlockWeights, hh: usize) -> Result<()> {
        self.write_kv_rows_at(li, x, blk, hh, 0)
    }

    /// Write the mixed-precision K/V rows of `x` into cache positions
    /// `[row0, row0 + x.rows)` — `row0 > 0` is the chunked-replay path.
    fn write_kv_rows_at(
        &mut self,
        li: usize,
        x: &Tensor,
        blk: &BlockWeights,
        hh: usize,
        row0: usize,
    ) -> Result<()> {
        let xn = crate::tensor::layer_norm(x, &blk.ln1_g, &blk.ln1_b, 1e-5);
        let mut k = crate::tensor::matmul(&xn, &blk.wk)?;
        crate::tensor::add_bias(&mut k, &blk.bk);
        let mut v = crate::tensor::matmul(&xn, &blk.wv)?;
        crate::tensor::add_bias(&mut v, &blk.bv);
        let (rows, d) = k.dims2()?;
        let dh = d / hh;
        for i in 0..rows {
            for head in 0..hh {
                for j in 0..dh {
                    let kt = &mut self.k_cache[li];
                    kt.data[(head * self.s_max + row0 + i) * dh + j] = k.row(i)[head * dh + j];
                    let vt = &mut self.v_cache[li];
                    vt.data[(head * self.s_max + row0 + i) * dh + j] = v.row(i)[head * dh + j];
                }
            }
        }
        Ok(())
    }

    /// Incrementally replay prompt rows `[lo, hi)` into the mixed cache —
    /// the live half of a scheduler `PrefillChunk` event. Chunks must
    /// arrive contiguously: `lo` equals the rows already replayed.
    ///
    /// Implementation is recompute-style: the full-precision prefix
    /// `[0, hi)` is re-derived from the retained prompt ids through the
    /// very same `embed` + [`native::baseline_block`] path the one-shot
    /// replay uses, and only the new rows `[lo, hi)` are written. Because
    /// the stream is causal, rows `[0, hi)` of the prefix pass are
    /// bit-identical to the same rows of the full pass — so chunked replay
    /// reproduces the one-shot cache exactly and generations are
    /// independent of the chunking schedule. The trade is recomputed host
    /// FLOPs (like the recompute-style eviction path), not memory: no
    /// shadow full-precision K/V buffers exist, and the mixed cache the
    /// admission gate accounts for is the session's whole footprint.
    pub fn replay_range(&mut self, lo: usize, hi: usize) -> Result<()> {
        let meta = &self.cluster.artifact.meta;
        if self.pending_prompt.is_empty() {
            bail!("no deferred prompt replay in progress (session is fully prefilled)");
        }
        if lo != self.len {
            bail!("chunks must be contiguous: expected lo={}, got lo={lo}", self.len);
        }
        if hi <= lo || hi > self.prompt_len {
            bail!("bad chunk range [{lo}, {hi}) for a {}-token prompt", self.prompt_len);
        }
        let hh = meta.n_heads;
        let (local_start, local_len) = self.local_range();
        // recompute the exact stream over the visible prefix [0, hi)
        let ids = Tensor::from_vec(
            &[hi, 1],
            self.pending_prompt[..hi].iter().map(|&v| v as f32).collect(),
        )?;
        let mut h = self.cluster.embed(&ids)?;
        let bias = native::causal_bias(hi);
        for li in 0..meta.n_layers {
            let blk = &self.cluster.native_blocks[li];
            // the tail device sees: local rows exact, remote rows quantized
            let xhat = self.cluster.artifact.codebooks[li].roundtrip(&h)?;
            let d = meta.d_model;
            let mut mixed = Tensor::zeros(&[hi - lo, d]);
            for g in lo..hi {
                let local = g >= local_start && g < local_start + local_len;
                let src = if local { h.row(g) } else { xhat.row(g) };
                let src = src.to_vec();
                mixed.row_mut(g - lo).copy_from_slice(&src);
            }
            self.write_kv_rows_at(li, &mixed, blk, hh, lo)?;
            h = native::baseline_block(&h, Some(&bias), blk, hh)?;
        }
        self.len = hi;
        if hi == self.prompt_len {
            self.pending_prompt = Vec::new(); // replay complete
        }
        Ok(())
    }

    /// Generate one token greedily; returns its id.
    pub fn step(&mut self) -> Result<usize> {
        let meta = &self.cluster.artifact.meta;
        if self.len < self.prompt_len {
            bail!(
                "prompt replay incomplete ({} of {} rows): deliver the remaining chunks first",
                self.len,
                self.prompt_len
            );
        }
        if self.len >= self.s_max {
            bail!("cache full ({} rows)", self.s_max);
        }
        let hh = meta.n_heads;
        let dh = meta.d_model / hh;
        // embed the most recent token at position len-1's successor; before
        // any generation this is the prompt's last token, not id 0
        let last_id = self.conditioning_token();
        let pos_idx = (self.len).min(meta.seq_len - 1); // clamp learned pos
        let embed = self.cluster.artifact.tensor("embed")?;
        let pos = self.cluster.artifact.tensor("pos")?;
        let mut h = Tensor::zeros(&[1, meta.d_model]);
        for j in 0..meta.d_model {
            h.row_mut(0)[j] = embed.row(last_id)[j] + pos.row(pos_idx)[j];
        }
        let valid: Vec<f32> = (0..self.s_max)
            .map(|i| if i < self.len { 1.0 } else { 0.0 })
            .collect();
        let valid_t = Tensor::from_vec(&[self.s_max], valid)?;

        for li in 0..meta.n_layers {
            let blk = &self.cluster.native_blocks[li];
            let (h_new, k_new, v_new) =
                native_decode_step(&h, &self.k_cache[li], &self.v_cache[li], &valid_t, blk, hh)?;
            // append k/v rows at position len
            for head in 0..hh {
                for j in 0..dh {
                    self.k_cache[li].data[(head * self.s_max + self.len) * dh + j] =
                        k_new.data[head * dh + j];
                    self.v_cache[li].data[(head * self.s_max + self.len) * dh + j] =
                        v_new.data[head * dh + j];
                }
            }
            h = h_new;
        }
        self.len += 1;
        let logits = native::lm_head(
            &h,
            &self.cluster.artifact.tensor("ln_f.g")?.data,
            &self.cluster.artifact.tensor("ln_f.b")?.data,
            self.cluster.artifact.tensor("head.w")?,
            &self.cluster.artifact.tensor("head.b")?.data,
        )?;
        let next = logits
            .row(0)
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(i, _)| i)
            .unwrap_or(0);
        self.generated.push(next);
        Ok(next)
    }

    /// The token id the next `step()` will embed.
    pub fn conditioning_token(&self) -> usize {
        next_conditioning_token(&self.generated, self.prompt_tail)
    }

    fn accounting_shape(&self) -> crate::model::TransformerShape {
        let meta = &self.cluster.artifact.meta;
        crate::model::TransformerShape {
            n_layers: meta.n_layers,
            d_model: meta.d_model,
            n_heads: meta.n_heads,
            d_ff: meta.d_ff,
            seq_len: meta.seq_len,
            elem_bytes: 4,
        }
    }

    /// The Appendix-G accounting function active for this session:
    /// classic prompt-scaled locality, or the positional variant when
    /// block sharing is on (prefix differences of which are block bytes).
    fn accounting_fn(
        &self,
    ) -> fn(&crate::model::TransformerShape, usize, usize, usize, usize, usize, usize) -> usize {
        if self.positional {
            crate::model::kv_cache_bytes_astra_positional
        } else {
            crate::model::kv_cache_bytes_astra_live
        }
    }

    /// Appendix G memory accounting for the cache's *current* occupancy:
    /// mixed-precision prompt rows (only those already replayed, so a
    /// deferred session's footprint grows chunk by chunk) plus
    /// full-precision generated rows.
    pub fn cache_bytes_mixed(&self) -> usize {
        let meta = &self.cluster.artifact.meta;
        self.accounting_fn()(
            &self.accounting_shape(),
            self.len.min(self.prompt_len),
            self.len.saturating_sub(self.prompt_len),
            4,
            self.cluster.partition.n_devices(),
            meta.groups,
            meta.codebook_size,
        )
    }

    /// Appendix G accounting at the full `s_max` budget — what this slot
    /// will hold once its decode budget is exhausted (the admission gate's
    /// per-slot ceiling).
    pub fn cache_bytes_budget(&self) -> usize {
        let meta = &self.cluster.artifact.meta;
        self.accounting_fn()(
            &self.accounting_shape(),
            self.prompt_len,
            self.s_max - self.prompt_len,
            4,
            self.cluster.partition.n_devices(),
            meta.groups,
            meta.codebook_size,
        )
    }

    /// Bytes of the first `tokens` prompt rows under this session's
    /// accounting — what a shared, block-covered prefix is worth. The live
    /// backend subtracts this from [`Self::cache_bytes_mixed`] when the
    /// rows are physically backed by the shared block store, so shared
    /// bytes are counted once across sessions.
    pub fn prefix_bytes(&self, tokens: usize) -> usize {
        let meta = &self.cluster.artifact.meta;
        self.accounting_fn()(
            &self.accounting_shape(),
            tokens.min(self.prompt_len),
            0,
            4,
            self.cluster.partition.n_devices(),
            meta.groups,
            meta.codebook_size,
        )
    }

    /// Copy the K/V rows of cache positions `[lo, hi)` out of every layer
    /// — the contribution of one finished KV block to the shared store.
    /// Returns one `(k_rows, v_rows)` pair per layer, each flattened
    /// `[heads x (hi - lo) x dh]`.
    pub fn export_rows(&self, lo: usize, hi: usize) -> Result<Vec<(Vec<f32>, Vec<f32>)>> {
        if lo >= hi || hi > self.len {
            bail!("export_rows: bad range [{lo}, {hi}) over {} replayed rows", self.len);
        }
        let meta = &self.cluster.artifact.meta;
        let hh = meta.n_heads;
        let dh = meta.d_model / hh;
        let mut out = Vec::with_capacity(meta.n_layers);
        for li in 0..meta.n_layers {
            let mut k = Vec::with_capacity(hh * (hi - lo) * dh);
            let mut v = Vec::with_capacity(hh * (hi - lo) * dh);
            for head in 0..hh {
                for i in lo..hi {
                    for j in 0..dh {
                        k.push(self.k_cache[li].data[(head * self.s_max + i) * dh + j]);
                        v.push(self.v_cache[li].data[(head * self.s_max + i) * dh + j]);
                    }
                }
            }
            out.push((k, v));
        }
        Ok(out)
    }

    /// Write previously exported rows into positions `[lo, hi)` — the
    /// attach side of prefix sharing. Blocks must arrive contiguously
    /// (`lo` equals the rows already present), before any replay of the
    /// suffix. Because positional locality makes the rows a pure function
    /// of the token-id prefix, an import followed by suffix-only
    /// [`Self::replay_range`] is bit-identical to a full replay.
    pub fn import_rows(
        &mut self,
        lo: usize,
        hi: usize,
        rows: &[(Vec<f32>, Vec<f32>)],
    ) -> Result<()> {
        let meta = &self.cluster.artifact.meta;
        if lo != self.len {
            bail!("import_rows: blocks must be contiguous (have {} rows, got lo={lo})", self.len);
        }
        if lo >= hi || hi > self.prompt_len {
            bail!("import_rows: bad range [{lo}, {hi}) for a {}-token prompt", self.prompt_len);
        }
        if rows.len() != meta.n_layers {
            bail!("import_rows: {} layers of rows for a {}-layer model", rows.len(), meta.n_layers);
        }
        let hh = meta.n_heads;
        let dh = meta.d_model / hh;
        let want = hh * (hi - lo) * dh;
        for (li, (k, v)) in rows.iter().enumerate() {
            if k.len() != want || v.len() != want {
                bail!("import_rows: layer {li} holds {} floats, expected {want}", k.len());
            }
            let mut idx = 0usize;
            for head in 0..hh {
                for i in lo..hi {
                    for j in 0..dh {
                        self.k_cache[li].data[(head * self.s_max + i) * dh + j] = k[idx];
                        self.v_cache[li].data[(head * self.s_max + i) * dh + j] = v[idx];
                        idx += 1;
                    }
                }
            }
        }
        self.len = hi;
        if self.len == self.prompt_len {
            self.pending_prompt = Vec::new(); // fully covered: nothing left to replay
        }
        Ok(())
    }
}

/// One decode step of one block, mirroring python `decode_step_block`.
/// Returns (h_out [1, D], k_new [H*dh], v_new [H*dh]).
fn native_decode_step(
    h_t: &Tensor,
    k_cache: &Tensor,
    v_cache: &Tensor,
    valid: &Tensor,
    blk: &BlockWeights,
    hh: usize,
) -> Result<(Tensor, Tensor, Tensor)> {
    let d = h_t.shape[1];
    let dh = d / hh;
    let s_max = k_cache.shape[1];
    let xn = crate::tensor::layer_norm(h_t, &blk.ln1_g, &blk.ln1_b, 1e-5);
    let mut q = crate::tensor::matmul(&xn, &blk.wq)?;
    crate::tensor::add_bias(&mut q, &blk.bq);
    let mut k_t = crate::tensor::matmul(&xn, &blk.wk)?;
    crate::tensor::add_bias(&mut k_t, &blk.bk);
    let mut v_t = crate::tensor::matmul(&xn, &blk.wv)?;
    crate::tensor::add_bias(&mut v_t, &blk.bv);

    let scale = 1.0 / (dh as f32).sqrt();
    let mut att_out = Tensor::zeros(&[1, d]);
    for head in 0..hh {
        // logits over cached rows (masked) + the new token itself
        let qh: Vec<f32> = q.row(0)[head * dh..(head + 1) * dh].to_vec();
        let mut logits = Vec::with_capacity(s_max + 1);
        for i in 0..s_max {
            if valid.data[i] < 0.5 {
                logits.push(f32::NEG_INFINITY);
                continue;
            }
            let mut acc = 0.0f32;
            for j in 0..dh {
                acc += qh[j] * k_cache.data[(head * s_max + i) * dh + j];
            }
            logits.push(acc * scale);
        }
        // self
        let mut acc = 0.0f32;
        for j in 0..dh {
            acc += qh[j] * k_t.row(0)[head * dh + j];
        }
        logits.push(acc * scale);
        // softmax
        let max = logits.iter().copied().fold(f32::NEG_INFINITY, f32::max);
        let mut sum = 0.0f32;
        for l in logits.iter_mut() {
            *l = (*l - max).exp();
            sum += *l;
        }
        // weighted value sum
        for j in 0..dh {
            let mut o = 0.0f32;
            for i in 0..s_max {
                if valid.data[i] < 0.5 {
                    continue;
                }
                o += logits[i] * v_cache.data[(head * s_max + i) * dh + j];
            }
            o += logits[s_max] * v_t.row(0)[head * dh + j];
            att_out.row_mut(0)[head * dh + j] = o / sum;
        }
    }
    let mut h1 = crate::tensor::matmul(&att_out, &blk.wo)?;
    crate::tensor::add_bias(&mut h1, &blk.bo);
    crate::tensor::add_inplace(&mut h1, h_t);
    // MLP
    let xn2 = crate::tensor::layer_norm(&h1, &blk.ln2_g, &blk.ln2_b, 1e-5);
    let mut m = crate::tensor::matmul(&xn2, &blk.w1)?;
    crate::tensor::add_bias(&mut m, &blk.b1);
    crate::tensor::gelu(&mut m);
    let mut m2 = crate::tensor::matmul(&m, &blk.w2)?;
    crate::tensor::add_bias(&mut m2, &blk.b2);
    crate::tensor::add_inplace(&mut m2, &h1);
    Ok((m2, k_t, v_t))
}

#[cfg(test)]
mod tests {
    use super::{next_conditioning_token, prompt_partition, DecodeSession};
    use crate::config::RunConfig;
    use crate::coordinator::{Cluster, TokenPartition};
    use crate::model::shape::VqSetting;
    use crate::model::TransformerShape;

    #[test]
    fn first_step_conditions_on_prompt_tail_not_token_zero() {
        // regression: before the fix, the first decode step embedded token
        // id 0 (`generated.last().unwrap_or(&0)`) regardless of the prompt
        assert_eq!(next_conditioning_token(&[], 173), 173);
        assert_ne!(next_conditioning_token(&[], 173), 0);
        // after generation starts, the newest generated token wins
        assert_eq!(next_conditioning_token(&[5, 9], 173), 9);
        // degenerate tail id 0 is still honoured (only correct when the
        // prompt really ends in token 0)
        assert_eq!(next_conditioning_token(&[], 0), 0);
    }

    #[test]
    fn prompt_partition_scales_and_tail_owns_remainder() {
        let full = TokenPartition::explicit(vec![4, 4, 4, 4]);
        assert_eq!(prompt_partition(&full, 16).sizes, vec![4, 4, 4, 4]);
        assert_eq!(prompt_partition(&full, 10).sizes, vec![2, 2, 2, 4]);
        assert_eq!(prompt_partition(&full, 3).sizes, vec![0, 0, 0, 3]);
        assert_eq!(prompt_partition(&full, 1).sizes, vec![0, 0, 0, 1]);
        // heterogeneous splits keep their proportions
        let het = TokenPartition::explicit(vec![8, 4, 4]);
        let p = prompt_partition(&het, 8);
        assert_eq!(p.total(), 8);
        assert!(p.sizes[0] >= p.sizes[1]);
    }

    fn tiny_cluster() -> Cluster {
        let shape = TransformerShape {
            n_layers: 2,
            d_model: 16,
            n_heads: 2,
            d_ff: 32,
            seq_len: 16,
            elem_bytes: 4,
        };
        let config = RunConfig { n_devices: 2, ..RunConfig::default() };
        Cluster::synthetic_decoder(&shape, 32, VqSetting::new(2, 8), config, 11).unwrap()
    }

    #[test]
    fn empty_prompt_is_rejected() {
        // regression: `prompt_tail` used to fall back to token 0 via
        // `unwrap_or(0)`, so an empty prompt silently decoded from a
        // fabricated context instead of erroring
        let cluster = tiny_cluster();
        let err = DecodeSession::new(&cluster, &[]).err().expect("empty prompt must fail");
        assert!(err.to_string().contains("non-empty"), "{err}");
        assert!(DecodeSession::with_budget(&cluster, &[], 8).is_err());
        // one token is the minimum viable prompt
        assert!(DecodeSession::new(&cluster, &[3]).is_ok());
    }

    #[test]
    fn variable_length_prompts_generate_deterministically() {
        let cluster = tiny_cluster();
        let vocab = cluster.artifact.meta.vocab_size;
        for plen in [1usize, 5, 9, 16] {
            let prompt: Vec<usize> = (0..plen).map(|i| (i * 5 + 1) % vocab).collect();
            let mut sess = DecodeSession::new(&cluster, &prompt).unwrap();
            assert_eq!(sess.len, plen);
            assert_eq!(sess.prompt_len, plen);
            let toks: Vec<usize> = (0..4).map(|_| sess.step().unwrap()).collect();
            assert!(toks.iter().all(|&t| t < vocab));
            assert_eq!(sess.len, plen + 4);
            // greedy decode reproduces bit-for-bit from a fresh session
            let mut again = DecodeSession::new(&cluster, &prompt).unwrap();
            let toks2: Vec<usize> = (0..4).map(|_| again.step().unwrap()).collect();
            assert_eq!(toks, toks2, "plen={plen}");
        }
        // prompts longer than the learned positions are rejected
        assert!(DecodeSession::new(&cluster, &[1usize; 17]).is_err());
    }

    #[test]
    fn chunked_replay_matches_one_shot_bit_for_bit() {
        // the chunked-prefill correctness anchor: delivering the prompt in
        // arbitrary contiguous chunks must build the exact cache the
        // one-shot replay builds (causality: a chunk advanced over the
        // exact K/V of its predecessors sees what the full pass saw)
        let cluster = tiny_cluster();
        let vocab = cluster.artifact.meta.vocab_size;
        let prompt: Vec<usize> = (0..13).map(|i| (i * 7 + 2) % vocab).collect();
        let mut full = DecodeSession::with_budget(&cluster, &prompt, 13 + 4).unwrap();
        let mut chunked = DecodeSession::deferred(&cluster, &prompt, 13 + 4).unwrap();
        // decode refuses to run mid-replay
        assert!(chunked.step().is_err());
        assert_eq!(chunked.cache_bytes_mixed(), 0);
        for (lo, hi) in [(0usize, 5usize), (5, 6), (6, 13)] {
            chunked.replay_range(lo, hi).unwrap();
            assert_eq!(chunked.len, hi);
        }
        assert_eq!(chunked.cache_bytes_mixed(), full.cache_bytes_mixed());
        for li in 0..cluster.artifact.meta.n_layers {
            assert_eq!(chunked.k_cache[li].data, full.k_cache[li].data, "K layer {li}");
            assert_eq!(chunked.v_cache[li].data, full.v_cache[li].data, "V layer {li}");
        }
        let a: Vec<usize> = (0..4).map(|_| full.step().unwrap()).collect();
        let b: Vec<usize> = (0..4).map(|_| chunked.step().unwrap()).collect();
        assert_eq!(a, b, "incremental replay diverged from one-shot replay");
    }

    #[test]
    fn replay_range_enforces_contiguity_and_bounds() {
        let cluster = tiny_cluster();
        let vocab = cluster.artifact.meta.vocab_size;
        let prompt = [1usize, 2, 3, 4, 5, 6];
        let mut sess = DecodeSession::deferred(&cluster, &prompt, 12).unwrap();
        assert!(sess.replay_range(2, 4).is_err(), "must start at 0");
        assert!(sess.replay_range(0, 0).is_err(), "empty chunk");
        assert!(sess.replay_range(0, 7).is_err(), "past the prompt");
        sess.replay_range(0, 3).unwrap();
        assert!(sess.replay_range(0, 4).is_err(), "must resume at row 3");
        // partial occupancy: fewer bytes than a fully replayed session
        let full = DecodeSession::with_budget(&cluster, &prompt, 12).unwrap();
        assert!(sess.cache_bytes_mixed() < full.cache_bytes_mixed());
        sess.replay_range(3, 6).unwrap();
        // replay complete: buffers freed, further chunks rejected
        assert!(sess.replay_range(6, 7).is_err());
        assert!(sess.step().unwrap() < vocab);
    }

    #[test]
    fn positional_block_import_plus_suffix_replay_is_bit_identical_to_full_replay() {
        // the prefix-cache correctness anchor: export block-aligned rows
        // from a donor, import them into a fresh session, replay only the
        // uncovered suffix — the raw cache floats must equal a full
        // positional replay, and greedy decode must be identical. This is
        // what makes attaching to shared blocks semantically free.
        let cluster = tiny_cluster();
        let vocab = cluster.artifact.meta.vocab_size;
        let prompt: Vec<usize> = (0..13).map(|i| (i * 7 + 2) % vocab).collect();
        let block = 4usize; // 3 full blocks cover [0, 12); token 12 is the suffix
        let mut donor = DecodeSession::with_budget_positional(&cluster, &prompt, 13 + 4).unwrap();
        let mut attached = DecodeSession::deferred_positional(&cluster, &prompt, 13 + 4).unwrap();
        assert!(attached.step().is_err(), "no decode before the prompt is complete");
        for k in 0..3 {
            let rows = donor.export_rows(k * block, (k + 1) * block).unwrap();
            attached.import_rows(k * block, (k + 1) * block, &rows).unwrap();
        }
        assert_eq!(attached.len, 12);
        // covered prefix is cheaper than the full prompt under accounting
        assert!(attached.cache_bytes_mixed() < donor.cache_bytes_mixed());
        assert_eq!(attached.prefix_bytes(12), attached.cache_bytes_mixed());
        attached.replay_range(12, 13).unwrap();
        assert_eq!(attached.cache_bytes_mixed(), donor.cache_bytes_mixed());
        for li in 0..cluster.artifact.meta.n_layers {
            assert_eq!(attached.k_cache[li].data, donor.k_cache[li].data, "K layer {li}");
            assert_eq!(attached.v_cache[li].data, donor.v_cache[li].data, "V layer {li}");
        }
        let a: Vec<usize> = (0..4).map(|_| donor.step().unwrap()).collect();
        let b: Vec<usize> = (0..4).map(|_| attached.step().unwrap()).collect();
        assert_eq!(a, b, "prefix attach changed greedy decode");
    }

    #[test]
    fn positional_rows_are_prefix_pure_across_prompt_lengths() {
        // the reason positional mode exists: the same leading token ids
        // must produce the same K/V rows whatever the prompt's total
        // length. Classic (prompt-scaled) locality does NOT have this
        // property, which is why blocks are only shared in positional mode.
        let cluster = tiny_cluster();
        let vocab = cluster.artifact.meta.vocab_size;
        let long: Vec<usize> = (0..12).map(|i| (i * 5 + 3) % vocab).collect();
        let short = long[..8].to_vec();
        let a = DecodeSession::with_budget_positional(&cluster, &long, 16).unwrap();
        let b = DecodeSession::with_budget_positional(&cluster, &short, 16).unwrap();
        let ra = a.export_rows(0, 8).unwrap();
        let rb = b.export_rows(0, 8).unwrap();
        assert_eq!(ra, rb, "shared 8-token prefix must yield identical rows");
        // accounting agrees with the positional Appendix-G function
        let meta = &cluster.artifact.meta;
        let want = crate::model::kv_cache_bytes_astra_positional(
            &a.accounting_shape(),
            12,
            0,
            4,
            cluster.partition.n_devices(),
            meta.groups,
            meta.codebook_size,
        );
        assert_eq!(a.cache_bytes_mixed(), want);
    }

    #[test]
    fn import_rows_enforces_contiguity_shape_and_bounds() {
        let cluster = tiny_cluster();
        let prompt = [1usize, 2, 3, 4, 5, 6, 7, 8];
        let donor = DecodeSession::with_budget_positional(&cluster, &prompt, 12).unwrap();
        let rows = donor.export_rows(0, 4).unwrap();
        let mut sess = DecodeSession::deferred_positional(&cluster, &prompt, 12).unwrap();
        assert!(sess.import_rows(4, 8, &donor.export_rows(4, 8).unwrap()).is_err(), "gap");
        assert!(sess.import_rows(0, 0, &rows).is_err(), "empty");
        assert!(sess.import_rows(0, 9, &rows).is_err(), "past the prompt");
        assert!(sess.import_rows(0, 3, &rows).is_err(), "row-count mismatch");
        sess.import_rows(0, 4, &rows).unwrap();
        assert_eq!(sess.len, 4);
        // replay continues from the imported edge only
        assert!(sess.replay_range(0, 4).is_err());
        sess.replay_range(4, 8).unwrap();
        assert!(sess.step().is_ok());
        // export refuses rows that were never written
        assert!(donor.export_rows(7, 8).is_ok());
        assert!(donor.export_rows(8, 9).is_err(), "past replayed rows");
    }

    #[test]
    fn cache_budget_caps_generation() {
        let cluster = tiny_cluster();
        let prompt = [1usize, 2, 3, 4, 5];
        // budget must at least hold the prompt
        assert!(DecodeSession::with_budget(&cluster, &prompt, 4).is_err());
        let mut sess = DecodeSession::with_budget(&cluster, &prompt, 7).unwrap();
        sess.step().unwrap();
        sess.step().unwrap();
        let err = sess.step().expect_err("cache must be full at s_max");
        assert!(err.to_string().contains("cache full"), "{err}");
        // budget accounting: current occupancy grows toward the ceiling
        assert!(sess.cache_bytes_mixed() <= sess.cache_bytes_budget());
        let fresh = DecodeSession::with_budget(&cluster, &prompt, 7).unwrap();
        assert!(fresh.cache_bytes_mixed() < sess.cache_bytes_mixed());
    }
}
