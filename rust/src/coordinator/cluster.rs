//! The live multi-device cluster: N virtual devices, real numerics, modeled
//! network, virtual-clock latency accounting.

use std::path::Path;
use std::sync::Arc;
use std::time::Instant;

use anyhow::{bail, Result};

use crate::comm::link::{LinkSpec, Network};
use crate::comm::message::Message;
use crate::config::RunConfig;
use crate::model::native;
use crate::runtime::{Artifact, Executor, ModelRuntime};
use crate::tensor::Tensor;

use super::partition::{decoder_bias, encoder_bias, TokenPartition};

/// Which engine executes block compute.
pub enum ComputeBackend {
    /// AOT PJRT executables (requires an even partition matching the
    /// artifact shapes — the shapes were fixed at lowering time).
    Pjrt(PjrtBank),
    /// Pure-rust reference path (any partition; used for cross-checking
    /// and heterogeneous splits).
    Native,
}

/// Per-layer PJRT executors with layer weights pre-bound.
pub struct PjrtBank {
    pub runtime: Arc<ModelRuntime>,
    pub astra_block: Vec<Executor>,
    pub vq_encode: Vec<Executor>,
    pub vq_decode: Vec<Executor>,
    pub baseline_block: Vec<Executor>,
    pub embed: Executor,
    pub head: Executor,
}

/// Latency + communication accounting for one prefill.
#[derive(Debug, Clone, Default)]
pub struct PrefillReport {
    /// end-to-end virtual latency (seconds) as an N-device deployment
    pub latency_s: f64,
    /// max over devices of summed compute segments
    pub compute_s: f64,
    /// latency_s - compute_s on the critical path device
    pub comm_s: f64,
    /// total VQ payload bits that crossed the network
    pub payload_bits: f64,
    /// payload bits / (transmitted tokens * layers): the paper's per-block
    /// bits-per-token (multiply by layers for the table's total column)
    pub bits_per_token_block: f64,
    pub messages: usize,
    /// packets dropped (loss without retransmission)
    pub packets_dropped: usize,
    pub fpar: f64,
}

/// One prefill's result.
pub struct PrefillOutput {
    pub logits: Tensor,
    pub report: PrefillReport,
    /// per-device final local rows (decoder decode-loop seed)
    pub locals: Vec<Tensor>,
}

/// The simulated cluster.
pub struct Cluster {
    pub artifact: Arc<Artifact>,
    pub backend: ComputeBackend,
    pub native_blocks: Vec<native::BlockWeights>,
    pub network: Network,
    pub partition: TokenPartition,
    pub config: RunConfig,
}

impl Cluster {
    /// Load artifacts and build the cluster. `use_pjrt=false` skips PJRT
    /// compilation (fast start; native numerics only).
    pub fn load(dir: &Path, config: RunConfig, use_pjrt: bool) -> Result<Cluster> {
        let artifact = Artifact::load(dir)?;
        Self::from_artifact(artifact, config, use_pjrt)
    }

    /// Build a cluster over an in-memory synthetic decoder bundle
    /// ([`Artifact::synthetic_decoder`]): random weights, native backend,
    /// `config.n_devices` devices. The self-contained entry point for the
    /// live continuous-batching path — tests, CI smoke runs, and
    /// `astra serve-cb --live` without trained artifacts.
    pub fn synthetic_decoder(
        shape: &crate::model::TransformerShape,
        vocab_size: usize,
        vq: crate::model::shape::VqSetting,
        config: RunConfig,
        seed: u64,
    ) -> Result<Cluster> {
        let artifact =
            Artifact::synthetic_decoder(shape, vocab_size, config.n_devices, vq, seed)?;
        Self::from_artifact(artifact, config, false)
    }

    pub fn from_artifact(artifact: Artifact, config: RunConfig, use_pjrt: bool) -> Result<Cluster> {
        let meta = &artifact.meta;
        let t = meta.seq_len;
        let n = config.n_devices;
        let partition = if config.token_split.is_empty() {
            TokenPartition::even(t, n)?
        } else {
            if config.token_split.len() != n || config.token_split.iter().sum::<usize>() != t {
                bail!("token_split must have {n} entries summing to {t}");
            }
            TokenPartition::explicit(config.token_split.clone())
        };
        let native_blocks = (0..meta.n_layers)
            .map(|li| artifact.native_block(li))
            .collect::<Result<Vec<_>>>()?;

        let even_matches_artifact =
            partition.sizes.iter().all(|&s| s == t / meta.n_devices) && n == meta.n_devices;
        let backend = if use_pjrt {
            if !even_matches_artifact {
                bail!(
                    "PJRT backend requires the even {}-device partition baked into the \
                     artifacts; use the native backend for heterogeneous splits",
                    meta.n_devices
                );
            }
            let runtime = Arc::new(ModelRuntime::load(artifact)?);
            let artifact = runtime.artifact.clone();
            let bank = PjrtBank {
                astra_block: runtime.layer_bank("astra_block")?,
                vq_encode: runtime.layer_bank("vq_encode")?,
                vq_decode: runtime.layer_bank("vq_decode")?,
                baseline_block: runtime.layer_bank("baseline_block")?,
                embed: runtime.executor_for_layer(
                    if artifact.meta.causal { "embed_dec" } else { "embed_enc" }, 0)?,
                head: runtime.executor_for_layer(
                    if artifact.meta.causal { "lm_head" } else { "head" }, 0)?,
                runtime: runtime.clone(),
            };
            return Ok(Cluster {
                artifact,
                backend: ComputeBackend::Pjrt(bank),
                native_blocks,
                network: Network::full_mesh(
                    n,
                    &link_spec(&config),
                    config.seed,
                ),
                partition,
                config,
            });
        } else {
            ComputeBackend::Native
        };
        Ok(Cluster {
            artifact: Arc::new(artifact),
            backend,
            native_blocks,
            network: Network::full_mesh(n, &link_spec(&config), config.seed),
            partition,
            config,
        })
    }

    fn meta(&self) -> &crate::runtime::artifact::ModelMeta {
        &self.artifact.meta
    }

    /// Token embedding for the whole sequence (leader-side).
    /// Encoder: x [T, patch_dim] -> [T, D]; decoder: x = one-hot ids.
    pub fn embed(&self, x: &Tensor) -> Result<Tensor> {
        let meta = self.meta();
        if meta.causal {
            // x: [T] token ids encoded as f32 in a [T,1] tensor
            let (t, _) = x.dims2()?;
            let embed = self.artifact.tensor("embed")?;
            let pos = self.artifact.tensor("pos")?;
            let d = meta.d_model;
            let mut out = Tensor::zeros(&[t, d]);
            for i in 0..t {
                let id = x.data[i] as usize;
                if id >= meta.vocab_size {
                    bail!("token id {id} >= vocab {}", meta.vocab_size);
                }
                for j in 0..d {
                    out.row_mut(i)[j] = embed.row(id)[j] + pos.row(i)[j];
                }
            }
            Ok(out)
        } else {
            let w = self.artifact.tensor("embed.w")?;
            let b = self.artifact.tensor("embed.b")?;
            let pos = self.artifact.tensor("pos")?;
            let mut h = crate::tensor::matmul(x, w)?;
            crate::tensor::add_bias(&mut h, &b.data);
            crate::tensor::add_inplace(&mut h, pos);
            Ok(h)
        }
    }

    /// Run one ASTRA prefill over the cluster.
    ///
    /// Encoder input: patches [T, patch_dim]; decoder input: ids [T, 1].
    pub fn prefill(&self, x: &Tensor) -> Result<PrefillOutput> {
        let meta = self.meta();
        let n = self.partition.n_devices();
        let t = meta.seq_len;
        let use_cls = meta.use_cls && !meta.causal;
        let bits_tok = self.artifact.codebooks[0].bits_per_token();
        let code_bits = crate::model::shape::ceil_log2(meta.codebook_size);

        // ---- embed (each device embeds its own chunk; time ∝ chunk) ----
        let t0 = Instant::now();
        let h_tok = self.embed(x)?;
        let embed_time = t0.elapsed().as_secs_f64();

        let cls = if use_cls { Some(self.artifact.tensor("cls")?.clone()) } else { None };
        let mut locals: Vec<Tensor> = (0..n)
            .map(|d| {
                let chunk = h_tok.rows(self.partition.start(d), self.partition.sizes[d])?;
                match &cls {
                    Some(c) => Tensor::vcat(&[c, &chunk]),
                    None => Ok(chunk),
                }
            })
            .collect::<Result<Vec<_>>>()?;

        let mut clock = vec![0.0f64; n];
        let mut compute = vec![0.0f64; n];
        for d in 0..n {
            let c = embed_time * self.partition.sizes[d] as f64 / t as f64;
            clock[d] += c;
            compute[d] += c;
        }

        let mut report = PrefillReport {
            fpar: self.partition.fpar(),
            bits_per_token_block: bits_tok as f64,
            ..Default::default()
        };
        // previous layer's decoded remote rows per device (loss fallback)
        let mut prev_xhat: Vec<Option<Tensor>> = vec![None; n];

        for li in 0..meta.n_layers {
            // ---- encode local content on each device ----
            let mut msgs: Vec<Message> = Vec::with_capacity(n);
            let mut enc_done = vec![0.0f64; n];
            for d in 0..n {
                let ncls = usize::from(use_cls);
                let content = locals[d].rows(ncls, locals[d].shape[0] - ncls)?;
                let tc = content.shape[0];
                let t0 = Instant::now();
                // §Perf iteration 2: the native VQ codec beats a PJRT
                // dispatch 5x at serving shapes (87 µs vs 463 µs — see
                // EXPERIMENTS.md), and its indices are bit-identical to the
                // kernels', so the codec always runs native; PJRT carries
                // the block compute.
                let indices: Vec<u32> = self.artifact.codebooks[li].encode(&content)?;
                let _ = tc;
                let dt = t0.elapsed().as_secs_f64();
                compute[d] += dt;
                enc_done[d] = clock[d] + dt;
                msgs.push(Message::vq(li, d, &indices, tc, meta.groups, code_bits)?);
            }

            // ---- exchange: multicast codes, max-merge arrival times ----
            // parallel-links model: each sender's multicast completes in one
            // chunk transfer; receiver d is ready when every peer's message
            // has arrived and its own encode is done.
            let mut ready = enc_done.clone();
            // receiver -> (concatenated remote indices in sender order,
            //              dropped row offsets within that concat)
            let mut recv_idx: Vec<Vec<u32>> = vec![Vec::new(); n];
            let mut recv_dropped: Vec<Vec<usize>> = vec![Vec::new(); n];
            for d in 0..n {
                let mut row_base = 0usize;
                for s in 0..n {
                    if s == d {
                        continue;
                    }
                    let m = &msgs[s];
                    report.messages += 1;
                    report.payload_bits += m.payload_bits() as f64;
                    let link = self.network.link(s, d);
                    let delivery = link.send(enc_done[s], m.wire_bytes());
                    ready[d] = ready[d].max(enc_done[s] + delivery.elapsed_s);
                    let tc = self.partition.sizes[s];
                    for ti in dropped_tokens(
                        &delivery.delivered, link.spec.mtu, tc, meta.groups, code_bits,
                    ) {
                        recv_dropped[d].push(row_base + ti);
                    }
                    report.packets_dropped +=
                        delivery.delivered.iter().filter(|&&x| !x).count();
                    recv_idx[d].extend(m.vq_indices()?);
                    row_base += tc;
                }
            }

            // ---- decode + MPA block per device ----
            let mut new_locals = Vec::with_capacity(n);
            for d in 0..n {
                let tr = t - self.partition.sizes[d];
                let t0 = Instant::now();
                // native decode (gather) — same §Perf rationale as encode
                let mut remote = self.artifact.codebooks[li].decode(&recv_idx[d], tr)?;
                if !recv_dropped[d].is_empty() {
                    substitute_stale(&mut remote, &recv_dropped[d], prev_xhat[d].as_ref());
                }
                let dt = t0.elapsed().as_secs_f64();
                compute[d] += dt;
                ready[d] += dt;
                prev_xhat[d] = Some(remote.clone());
                let tl = locals[d].shape[0];
                let tr = remote.shape[0];
                let bias = if meta.causal {
                    decoder_bias(&self.partition, d)
                } else {
                    encoder_bias(tl, tr)
                };
                let t0 = Instant::now();
                let out = match &self.backend {
                    ComputeBackend::Pjrt(bank) => bank.astra_block[li]
                        .run(&[&locals[d], &remote, &bias])?
                        .remove(0),
                    ComputeBackend::Native => native::astra_block(
                        &locals[d], &remote, Some(&bias), &self.native_blocks[li], meta.n_heads,
                    )?,
                };
                let dt = t0.elapsed().as_secs_f64();
                compute[d] += dt;
                clock[d] = ready[d] + dt;
                new_locals.push(out);
            }
            locals = new_locals;
        }

        // ---- aggregate + head ----
        let (logits, head_time, head_dev) = if use_cls {
            // CLS replicas travel to the leader (device 0): D f32 each
            let mut ready = clock[0];
            for d in 1..n {
                let bytes = meta.d_model * 4 + crate::comm::message::HEADER_BYTES;
                let arr = clock[d] + self.network.link(d, 0).send(clock[d], bytes).elapsed_s;
                ready = ready.max(arr);
            }
            let cls_rows: Vec<Tensor> = locals
                .iter()
                .map(|l| l.rows(0, 1))
                .collect::<Result<Vec<_>>>()?;
            let refs: Vec<&Tensor> = cls_rows.iter().collect();
            let stack = Tensor::vcat(&refs)?;
            let t0 = Instant::now();
            let logits = match &self.backend {
                ComputeBackend::Pjrt(bank) => bank.head.run(&[&stack])?.remove(0),
                ComputeBackend::Native => native::head(
                    &stack,
                    &self.artifact.tensor("ln_f.g")?.data,
                    &self.artifact.tensor("ln_f.b")?.data,
                    self.artifact.tensor("head.w")?,
                    &self.artifact.tensor("head.b")?.data,
                )?,
            };
            clock[0] = ready + t0.elapsed().as_secs_f64();
            (logits, t0.elapsed().as_secs_f64(), 0usize)
        } else {
            // decoder: tail device computes the LM head over its local rows
            let d = n - 1;
            let t0 = Instant::now();
            let logits = match &self.backend {
                ComputeBackend::Pjrt(bank) => bank.head.run(&[&locals[d]])?.remove(0),
                ComputeBackend::Native => native::lm_head(
                    &locals[d],
                    &self.artifact.tensor("ln_f.g")?.data,
                    &self.artifact.tensor("ln_f.b")?.data,
                    self.artifact.tensor("head.w")?,
                    &self.artifact.tensor("head.b")?.data,
                )?,
            };
            clock[d] = clock[d] + t0.elapsed().as_secs_f64();
            (logits, t0.elapsed().as_secs_f64(), d)
        };
        compute[head_dev] += head_time;

        report.latency_s = clock.iter().copied().fold(0.0, f64::max);
        report.compute_s = compute.iter().copied().fold(0.0, f64::max);
        report.comm_s = (report.latency_s - report.compute_s).max(0.0);
        Ok(PrefillOutput { logits, report, locals })
    }

    /// Single-device baseline: full-precision blocks over the whole
    /// sequence (the paper's "Original Model" row). Returns logits +
    /// measured wall latency.
    pub fn prefill_single_device(&self, x: &Tensor) -> Result<(Tensor, f64)> {
        let meta = self.meta();
        let t0 = Instant::now();
        let h_tok = self.embed(x)?;
        let use_cls = meta.use_cls && !meta.causal;
        let mut h = if use_cls {
            Tensor::vcat(&[self.artifact.tensor("cls")?, &h_tok])?
        } else {
            h_tok
        };
        let t_all = h.shape[0];
        let bias = if meta.causal {
            native::causal_bias(t_all)
        } else {
            Tensor::zeros(&[t_all, t_all])
        };
        for li in 0..meta.n_layers {
            h = match &self.backend {
                ComputeBackend::Pjrt(bank) => {
                    bank.baseline_block[li].run(&[&h, &bias])?.remove(0)
                }
                ComputeBackend::Native => native::baseline_block(
                    &h, Some(&bias), &self.native_blocks[li], meta.n_heads,
                )?,
            };
        }
        let logits = if use_cls {
            let cls_row = h.rows(0, 1)?;
            native::head(
                &cls_row,
                &self.artifact.tensor("ln_f.g")?.data,
                &self.artifact.tensor("ln_f.b")?.data,
                self.artifact.tensor("head.w")?,
                &self.artifact.tensor("head.b")?.data,
            )?
        } else {
            native::lm_head(
                &h,
                &self.artifact.tensor("ln_f.g")?.data,
                &self.artifact.tensor("ln_f.b")?.data,
                self.artifact.tensor("head.w")?,
                &self.artifact.tensor("head.b")?.data,
            )?
        };
        Ok((logits, t0.elapsed().as_secs_f64()))
    }
}

fn link_spec(config: &RunConfig) -> LinkSpec {
    LinkSpec::ideal(config.bandwidth_mbps)
        .with_latency(config.latency_s)
        .with_loss(config.loss_rate, config.retransmit)
}

/// Map dropped packets to the token rows whose codes they carried.
fn dropped_tokens(
    delivered: &[bool],
    mtu: usize,
    tokens: usize,
    groups: usize,
    code_bits: usize,
) -> Vec<usize> {
    let bits_per_token = groups * code_bits;
    let mut out = Vec::new();
    for (p, &ok) in delivered.iter().enumerate() {
        if ok {
            continue;
        }
        let bit_lo = p * mtu * 8;
        let bit_hi = (p + 1) * mtu * 8;
        let tok_lo = bit_lo / bits_per_token.max(1);
        let tok_hi = bit_hi.div_ceil(bits_per_token.max(1)).min(tokens);
        out.extend(tok_lo..tok_hi);
    }
    out.dedup();
    out
}

/// Replace lost rows with the previous layer's decoded rows at the same
/// offset (stale-code fallback; the remote layout is identical layer to
/// layer) or zeros at the first layer.
fn substitute_stale(xhat: &mut Tensor, dropped: &[usize], prev: Option<&Tensor>) {
    for &ti in dropped {
        if ti >= xhat.shape[0] {
            continue;
        }
        match prev {
            Some(p) if ti < p.shape[0] => {
                let src = p.row(ti).to_vec();
                xhat.row_mut(ti).copy_from_slice(&src);
            }
            _ => {
                for v in xhat.row_mut(ti) {
                    *v = 0.0;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dropped_token_mapping() {
        // 10 bits/token, mtu 5 bytes = 40 bits = 4 tokens/packet
        let delivered = vec![true, false, true];
        let d = dropped_tokens(&delivered, 5, 12, 1, 10);
        assert_eq!(d, vec![4, 5, 6, 7]);
        // all delivered
        assert!(dropped_tokens(&[true, true], 5, 12, 1, 10).is_empty());
    }

    #[test]
    fn substitute_stale_zeros_without_prev() {
        let mut x = Tensor::from_vec(&[2, 2], vec![1.0, 2.0, 3.0, 4.0]).unwrap();
        substitute_stale(&mut x, &[1], None);
        assert_eq!(x.data, vec![1.0, 2.0, 0.0, 0.0]);
        // with prev: copies the stale row
        let prev = Tensor::from_vec(&[2, 2], vec![9.0, 9.0, 8.0, 8.0]).unwrap();
        let mut y = Tensor::from_vec(&[2, 2], vec![1.0, 2.0, 3.0, 4.0]).unwrap();
        substitute_stale(&mut y, &[0], Some(&prev));
        assert_eq!(y.data, vec![9.0, 9.0, 3.0, 4.0]);
    }
}
