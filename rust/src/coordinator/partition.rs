//! Token-to-device partitioning: even, heterogeneous, and randomized
//! splits; FPAR accounting (Appendix D); attention-bias construction for
//! the per-device AOT graphs.

use anyhow::{bail, Result};

use crate::model::native::NEG;
use crate::tensor::Tensor;
use crate::util::rng::Rng;

/// Contiguous partition of T content tokens over N devices.
#[derive(Debug, Clone, PartialEq)]
pub struct TokenPartition {
    pub sizes: Vec<usize>,
}

impl TokenPartition {
    /// Even split (requires divisibility, like the paper's main setting).
    pub fn even(t: usize, n: usize) -> Result<TokenPartition> {
        if n == 0 || t % n != 0 {
            bail!("cannot split {t} tokens evenly over {n} devices");
        }
        Ok(TokenPartition { sizes: vec![t / n; n] })
    }

    /// Heterogeneous split proportional to device speeds (stronger devices
    /// take more tokens — paper §4.2 "Heterogeneous Devices").
    pub fn proportional(t: usize, speeds: &[f64]) -> Result<TokenPartition> {
        if speeds.is_empty() || speeds.iter().any(|&s| s <= 0.0) {
            bail!("speeds must be positive");
        }
        let total: f64 = speeds.iter().sum();
        let mut sizes: Vec<usize> =
            speeds.iter().map(|s| ((s / total) * t as f64).floor() as usize).collect();
        // distribute the remainder to the fastest devices
        let mut rem = t - sizes.iter().sum::<usize>();
        let mut order: Vec<usize> = (0..speeds.len()).collect();
        order.sort_by(|&a, &b| speeds[b].partial_cmp(&speeds[a]).unwrap());
        let mut i = 0;
        while rem > 0 {
            sizes[order[i % order.len()]] += 1;
            rem -= 1;
            i += 1;
        }
        Ok(TokenPartition { sizes })
    }

    /// Random split: each token assigned uniformly (training-style
    /// randomized mapping, Appendix D). Contiguity is *not* preserved; the
    /// returned partition records only sizes — use `random_assign` for the
    /// full mapping.
    pub fn random(rng: &mut Rng, t: usize, n: usize) -> TokenPartition {
        let mut sizes = vec![0usize; n];
        for _ in 0..t {
            sizes[rng.below(n)] += 1;
        }
        TokenPartition { sizes }
    }

    pub fn explicit(sizes: Vec<usize>) -> TokenPartition {
        TokenPartition { sizes }
    }

    pub fn n_devices(&self) -> usize {
        self.sizes.len()
    }

    pub fn total(&self) -> usize {
        self.sizes.iter().sum()
    }

    /// Start offset of device d's contiguous chunk.
    pub fn start(&self, d: usize) -> usize {
        self.sizes[..d].iter().sum()
    }

    /// Full-Precision Attention Rate: sum_k (n_k / T)^2 (Appendix D Eq. 35).
    pub fn fpar(&self) -> f64 {
        let t = self.total() as f64;
        self.sizes.iter().map(|&s| (s as f64 / t).powi(2)).sum()
    }

    /// Variance of per-device token counts (Eq. 36 relates it to FPAR).
    pub fn size_variance(&self) -> f64 {
        let k = self.sizes.len() as f64;
        let mu = self.total() as f64 / k;
        self.sizes.iter().map(|&s| (s as f64 - mu).powi(2)).sum::<f64>() / k
    }
}

/// Bias for device `d`'s per-device MPA graph in the *encoder* setting:
/// queries = [CLS replica, local tokens]; keys = [local | remote-hat].
/// Everything is admissible (local rows full-precision, remote rows are the
/// dequantized codes — the graph's key layout already encodes the split),
/// so the bias is all-zeros; kept explicit for shape-checking and to share
/// the code path with the causal variant.
pub fn encoder_bias(tl: usize, tr: usize) -> Tensor {
    Tensor::zeros(&[tl, tl + tr])
}

/// Bias for device `d` in the *decoder* setting: causal over global
/// positions. Local rows are positions [start, start+tl); remote columns
/// are the other devices' chunks in device order.
pub fn decoder_bias(part: &TokenPartition, d: usize) -> Tensor {
    let tl = part.sizes[d];
    let t = part.total();
    let tr = t - tl;
    let start = part.start(d);
    let mut bias = Tensor::zeros(&[tl, tl + tr]);
    for qi in 0..tl {
        let qpos = start + qi;
        // local columns
        for kj in 0..tl {
            if start + kj > qpos {
                bias.data[qi * (tl + tr) + kj] = NEG;
            }
        }
        // remote columns: device order, skipping d
        let mut col = tl;
        for dd in 0..part.n_devices() {
            if dd == d {
                continue;
            }
            let s = part.start(dd);
            for kj in 0..part.sizes[dd] {
                if s + kj > qpos {
                    bias.data[qi * (tl + tr) + col] = NEG;
                }
                col += 1;
            }
        }
    }
    bias
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn even_and_errors() {
        let p = TokenPartition::even(16, 4).unwrap();
        assert_eq!(p.sizes, vec![4, 4, 4, 4]);
        assert_eq!(p.start(2), 8);
        assert!(TokenPartition::even(10, 4).is_err());
        assert!(TokenPartition::even(10, 0).is_err());
    }

    #[test]
    fn proportional_sums_and_favors_fast() {
        let p = TokenPartition::proportional(100, &[2.0, 1.0, 1.0]).unwrap();
        assert_eq!(p.total(), 100);
        assert!(p.sizes[0] > p.sizes[1]);
        assert!(TokenPartition::proportional(10, &[1.0, -1.0]).is_err());
    }

    #[test]
    fn fpar_bounds_and_monotonicity() {
        let even = TokenPartition::even(64, 4).unwrap();
        assert!((even.fpar() - 0.25).abs() < 1e-12);
        let skew = TokenPartition::explicit(vec![32, 16, 8, 8]);
        assert!(skew.fpar() > even.fpar());
        let all = TokenPartition::explicit(vec![64, 0, 0, 0]);
        assert!((all.fpar() - 1.0).abs() < 1e-12);
        // Eq. 36: Var = T^2/K * (FPAR - 1/K)
        let t = 64.0f64;
        let k = 4.0;
        let want = t * t / k * (skew.fpar() - 1.0 / k);
        assert!((skew.size_variance() - want).abs() < 1e-9);
    }

    #[test]
    fn random_partition_sums() {
        let mut rng = Rng::new(0);
        let p = TokenPartition::random(&mut rng, 128, 4);
        assert_eq!(p.total(), 128);
        assert!(p.fpar() >= 0.25 - 1e-12);
    }

    #[test]
    fn decoder_bias_causality() {
        let p = TokenPartition::even(8, 2).unwrap();
        // device 1 owns positions 4..8; remote = device 0 positions 0..4
        let b = decoder_bias(&p, 1);
        assert_eq!(b.shape, vec![4, 8]);
        // first local query (pos 4): local col 0 (pos 4) ok, col 1 (pos 5) masked
        assert_eq!(b.data[0], 0.0);
        assert_eq!(b.data[1], NEG);
        // all remote (pos 0..4) visible to pos 4
        for c in 4..8 {
            assert_eq!(b.data[c], 0.0);
        }
        // device 0: remote (device 1, pos 4..8) all masked for its queries
        let b0 = decoder_bias(&p, 0);
        for qi in 0..4 {
            for c in 4..8 {
                assert_eq!(b0.data[qi * 8 + c], NEG, "q{qi} c{c}");
            }
        }
    }

    #[test]
    fn encoder_bias_all_open() {
        let b = encoder_bias(5, 12);
        assert_eq!(b.shape, vec![5, 17]);
        assert!(b.data.iter().all(|&v| v == 0.0));
    }
}
