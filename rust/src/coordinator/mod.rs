//! The ASTRA coordinator — the paper's system contribution.
//!
//! Orchestrates sequence-parallel prefill across N (simulated) devices:
//! per transformer block, each device VQ-encodes its local token
//! embeddings, multicasts the bit-packed codes over the simulated network,
//! decodes peers' codes, and runs the Mixed-Precision Attention block via
//! the AOT PJRT executables (or the pure-rust native path). Distributed
//! Class Token replicas are pooled into the prediction head; decoder
//! configurations follow with an autoregressive decode loop on the device
//! owning the sequence tail.
//!
//! Device parallelism is *virtual-clock simulated*: compute segments are
//! timed for real (PJRT/native wall time) and combined with modeled link
//! delays by max-merging per-device clocks, exactly as independent devices
//! would overlap. On this 1-core host, thread-per-device would serialize
//! anyway; the virtual clock keeps reported latencies faithful to an
//! actual N-device deployment (DESIGN.md §2).

pub mod cluster;
pub mod decode;
pub mod partition;

pub use cluster::{Cluster, ComputeBackend, PrefillOutput, PrefillReport};
pub use decode::{step_batch, DecodeSession, SessionBuilder};
pub use partition::TokenPartition;
