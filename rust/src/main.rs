//! `astra` — launcher CLI.
//!
//! Subcommands:
//!   serve      run the threaded multi-device cluster on the AOT artifacts
//!              and serve a synthetic request stream (reports latency +
//!              throughput + bits-per-token)
//!   run        one prefill through the cluster, printing logits
//!   simulate   cost-model latency for a (model, strategy, bandwidth) point
//!   calibrate  measure native/PJRT compute throughput on this host
//!   info       print artifact manifest summary
//!
//! `astra-eval` (separate binary) regenerates every paper table/figure.

use anyhow::Result;
use astra::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::from_env(&[
        "help",
        "verbose",
        "native",
        "no-pjrt",
        "live",
        "assert-invariants",
        "prefix-cache",
    ])?;
    if args.flag("help") || args.positional.is_empty() {
        print_help();
        return Ok(());
    }
    match args.command()? {
        "serve" => astra::server::cli::serve(&args),
        "serve-cb" => astra::server::cli::serve_cb(&args),
        "soak" => astra::server::cli::soak(&args),
        "run" => astra::server::cli::run_once(&args),
        "simulate" => astra::server::cli::simulate(&args),
        "calibrate" => astra::server::cli::calibrate(&args),
        "bench-gate" => astra::util::bench::gate_cli(&args),
        "info" => astra::server::cli::info(&args),
        other => {
            eprintln!("unknown subcommand `{other}`");
            print_help();
            std::process::exit(2);
        }
    }
}

fn print_help() {
    println!(
        "astra — communication-efficient multi-device transformer inference

USAGE: astra <subcommand> [options]

SUBCOMMANDS
  serve      serve a synthetic request stream on the simulated cluster
             --artifacts DIR --devices N --bandwidth MBPS --requests N
             --arrival-rate R --loss P --seed S
  serve-cb   continuous-batching load test on the cost model, with the
             batch-1 FIFO baseline on the same Poisson stream
             --model M --tokens T --devices N --strategy S --bandwidth MBPS
             --trace constant|markov --rate R --horizon S --slots K
             --max-batch B --max-wait S --decode-tokens D --slo S --seed S
             --kv-cap BYTES (mixed-KV admission cap, 0 = off)
             --chunk-tokens C (Sarathi-style chunked prefill: mix at most
             C prompt tokens per iteration into the decode steps instead
             of monopolizing the cluster; 0 = off)
             --prefix-cache: radix-tree prefix reuse over block-based KV —
             a request sharing a block-aligned prompt prefix with a
             resident or recently-freed cache attaches to those blocks
             and replays only the suffix (PrefixHit events, hit-rate
             report)  --kv-block-tokens B (tokens per shared block)
             --prompt-groups G (map request ids onto G prompt streams so
             prompts actually share prefixes; 0 = all-unique)
             --swap-bandwidth-mbps M: swap-style preemption — a
             KV-pressure victim's cache moves to host memory over an
             M-Mbps link instead of recomputing, whenever the priced
             round trip beats the modeled recompute (SwapOut/SwapIn
             events; needs --kv-cap)
             --decode-jitter J: seeded per-request decode budgets in
             decode-tokens +/- J, so same-length waves stop completing
             in lockstep
             --policy fifo|prefix-aware|slo-class: the scheduling-policy
             layer — who is admitted next, who loses a slot under KV
             pressure, whether to preempt proactively for SLOs. fifo
             (default) reproduces the pre-policy event streams bit for
             bit; prefix-aware orders eligible admissions by radix-tree
             covered-prefix length (aging-bounded, needs --prefix-cache
             to matter); slo-class schedules priority classes
             --classes d0,d1,...: per-class latency deadlines in seconds
             (higher class index = higher priority; ids map round-robin;
             <=0 = no deadline). Adds per-class attainment/p95/goodput
             report rows under any policy, and implies --policy
             slo-class unless one is given
             --age-bound S: seconds of queueing per aging step for the
             reordering policies (starvation bound; default 0.5)
             --slo-preempt-budget K: victims the slo-class proactive
             preemption hook may evict per iteration (default 1, the
             historical single-victim behavior)
             --slo-preempt-cost S: budget, in modeled seconds per
             iteration, for the *cost* of proactive SLO evictions — each
             victim is priced at its swap round trip or recompute time
             (whichever the engine would pick) and victims past the
             budget stay resident (0 = unpriced)
             --arrivals poisson|diurnal|bursty: generative arrival trace
             (default poisson reproduces the classic stream bit for bit).
             diurnal sweeps the rate sinusoidally from --rate up to
             --peak-rate over --period seconds; bursty drives it with a
             seeded Markov chain over --burst-states levels in
             [--rate, --peak-rate], dwelling --dwell seconds per state
             --peak-rate R (default 3x --rate)  --period S  --dwell S
             --burst-states K
             --tenants w0,w1,...: weighted multi-tenant mix — each arrival
             draws a tenant by weight and its id maps tenant k to QoS
             class k under --classes
             --patience S: streaming-client patience — a request whose
             client has seen no token for longer than its patience is
             cancelled mid-decode (slot, KV blocks, swap/checkpoint state
             all freed; Cancelled events). 0 = infinitely patient clients,
             the exact legacy path. Enables per-token delivery timestamps
             and the cancelled / wasted-decode-tokens / time-to-token rows
             --patience-spread F: log-uniform per-request patience spread
             (factor around --patience; 0 = uniform patience)
             --length-tail A: bounded-Pareto decode-length tail with
             exponent A over [1, --decode-tokens] — a few long requests,
             many short ones (0 = all full-length)
             --replicas N: run N engine replicas under one deterministic
             cluster event loop (fleet mode; works with and without
             --live). --replicas 1 is exactly the single-engine path
             --route-policy round-robin|least-loaded|prefix-affinity:
             which replica each arrival joins (fleet mode; prefix-affinity
             scores each replica's cached prompt prefix against its load
             skew over per-replica shadow radix digests)
             --drain-at S: remove replica 0 at virtual time S — its slots
             evict, its queue spills to the survivors via the route policy
             --fault-seed S: seeded deterministic fault plan over the fleet
             (replica kills mid-decode, link degradation windows, swap-tier
             slowdown, arrival bursts — all events on the virtual clock;
             needs --replicas >= 2 for kills). A killed replica's queue and
             host tier are lost; its in-flight requests re-route and either
             restore from a fleet-held checkpoint or replay from the prompt
             --checkpoint-every K: checkpoint each decoding slot's KV to
             the host tier every K generated tokens, priced over the
             --swap-bandwidth-mbps link (0 = off; needs swap + decode) —
             the restore tier the fault path recovers from
             --live: drive real DecodeSessions (variable-length prompts,
             mixed-precision KV caches, greedy generations) through the
             same slot scheduler; uses --artifacts DIR when a decoder
             bundle exists, else a synthetic tiny decoder
             --assert-invariants: print the live smoke-invariant checklist
             (full generations, zero kv_violations, zero TTFT anomalies);
             failures name the broken invariant before the non-zero exit
  soak       chaos soak: run --seeds N consecutive seeded fault plans
             over a --replicas fleet on the cost model and check the
             invariant checklist on every run (no request lost or
             double-completed, zero KV violations); a failing seed is a
             standalone repro via serve-cb --fault-seed S
             --seeds N --replicas R --fault-seed BASE --rate R --horizon S
             plus the serve-cb engine flags (--model --slots --kv-cap
             --swap-bandwidth-mbps --checkpoint-every ...)
  bench-gate deterministic bench-regression gate for CI
             --baseline FILE --current FILE --tolerance 0.02
             fails listing every modeled metric that regressed
  run        single prefill through the cluster; prints logits and
             per-layer communication accounting
             --artifacts DIR --devices N --bandwidth MBPS [--native]
  simulate   analytic latency for a model/strategy/bandwidth point
             --model vit-base|gpt2-s|gpt2-m|llama3-8b --tokens T
             --devices N --strategy single|tp|sp|bp-ag|bp-sp|astra
             --nb NB --vq g16k1024 --bandwidth MBPS
  calibrate  measure this host's matmul + PJRT block throughput
  info       print the artifact manifest summary  --artifacts DIR"
    );
}
