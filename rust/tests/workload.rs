//! Workload-subsystem harness: generative arrival traces, the streaming
//! client model, and the acceptance property the subsystem exists for —
//! a cancellation-aware engine wastes strictly fewer decode tokens than a
//! cancellation-blind one at equal-or-better useful throughput, under a
//! bursty cancel-heavy seeded trace.
//!
//! The blind baseline is the same engine with a patience deadline too
//! large to ever fire: the sweep stays armed (so per-token delivery
//! streams are recorded) but no request is ever cancelled, which the
//! differential tests pin as bit-identical to the legacy patience-off
//! path. Waste is then scored post hoc with the *same* pure accounting
//! ([`wasted_deliveries`]) and the *same* per-request patience draws on
//! both runs, so the comparison is apples to apples.

use astra::comm::trace::BandwidthTrace;
use astra::model::shape::{TransformerShape, VqSetting};
use astra::parallel::strategies::{Strategy, StrategyKind};
use astra::server::batcher::poisson_arrivals;
use astra::server::live::live_arrivals;
use astra::server::scheduler::{CbConfig, CbEngine, CbReport};
use astra::sim::latency::SimParams;
use astra::util::rng::Rng;
use astra::workload::{
    abandon_time, patience_for, tail_budget, wasted_deliveries, ArrivalProcess, PromptLengths,
    WorkloadSpec,
};

fn engine(cfg: CbConfig) -> CbEngine {
    CbEngine::new(
        TransformerShape::paper_encoder(1024),
        Strategy::new(StrategyKind::Astra { vq: VqSetting::new(16, 1024) }, 4),
        SimParams::paper_encoder(),
        BandwidthTrace::constant(100.0, 1e9),
        cfg,
    )
}

#[test]
fn poisson_spec_is_bit_identical_to_the_legacy_generators() {
    // the anchor the whole subsystem hangs off: the plain-Poisson spec
    // consumes the RNG stream exactly like the generators it replaces, so
    // every arrival time and prompt length matches to the bit
    for seed in [0u64, 7, 42, 1234] {
        let spec = WorkloadSpec::poisson(seed, 8.0, 15.0, 1024);
        let legacy = poisson_arrivals(&mut Rng::new(seed), 8.0, 15.0, 1024);
        let generated = spec.generate();
        assert_eq!(generated.len(), legacy.len(), "seed {seed}");
        for (a, b) in generated.iter().zip(&legacy) {
            assert_eq!(a.id, b.id, "seed {seed}");
            assert_eq!(a.tokens, b.tokens, "seed {seed}");
            assert_eq!(a.arrival_s.to_bits(), b.arrival_s.to_bits(), "seed {seed}");
        }

        // and the variable-prompt convention matches live_arrivals
        let spec = WorkloadSpec {
            prompts: PromptLengths::UniformHalf(64),
            ..WorkloadSpec::poisson(seed, 12.0, 10.0, 64)
        };
        let legacy = live_arrivals(&mut Rng::new(seed), 12.0, 10.0, 64);
        let generated = spec.generate();
        assert_eq!(generated.len(), legacy.len(), "seed {seed}");
        for (a, b) in generated.iter().zip(&legacy) {
            assert_eq!((a.id, a.tokens), (b.id, b.tokens), "seed {seed}");
            assert_eq!(a.arrival_s.to_bits(), b.arrival_s.to_bits(), "seed {seed}");
        }
    }
}

#[test]
fn time_varying_traces_are_deterministic_sorted_and_rate_shaped() {
    let diurnal = WorkloadSpec {
        seed: 5,
        horizon_s: 40.0,
        process: ArrivalProcess::Diurnal { base_rate: 2.0, peak_rate: 20.0, period_s: 40.0 },
        prompts: PromptLengths::Fixed(1024),
        tenant_weights: Vec::new(),
    };
    let bursty = WorkloadSpec {
        process: ArrivalProcess::MarkovBursts {
            lo_rate: 2.0,
            hi_rate: 20.0,
            states: 5,
            dwell_s: 2.0,
        },
        ..diurnal.clone()
    };
    for spec in [&diurnal, &bursty] {
        let a = spec.generate();
        assert_eq!(a, spec.generate(), "same spec must yield the same trace");
        assert!(!a.is_empty());
        assert!(a.iter().all(|r| r.arrival_s >= 0.0 && r.arrival_s < spec.horizon_s));
        assert!(a.windows(2).all(|w| w[0].arrival_s <= w[1].arrival_s), "unsorted");
        // thinning renumbers accepted candidates densely from 1
        assert!(a.iter().enumerate().all(|(i, r)| r.id == i as u64 + 1));
        // thinned count sits strictly inside the lo/hi Poisson envelopes
        assert!(a.len() as f64 > 0.5 * 2.0 * spec.horizon_s, "{}", a.len());
        assert!((a.len() as f64) < 20.0 * spec.horizon_s, "{}", a.len());
    }
    // the diurnal curve concentrates mass mid-horizon (the peak of the
    // single period): the middle half must out-arrive the outer half
    let a = diurnal.generate();
    let mid = a.iter().filter(|r| r.arrival_s >= 10.0 && r.arrival_s < 30.0).count();
    assert!(2 * mid > a.len(), "{mid} of {}", a.len());
}

#[test]
fn tenant_mixes_map_ids_onto_qos_classes_by_weight() {
    let spec = WorkloadSpec {
        tenant_weights: vec![1.0, 3.0],
        ..WorkloadSpec::poisson(9, 20.0, 30.0, 1024)
    };
    let a = spec.generate();
    assert!(a.len() > 100, "{}", a.len());
    assert_eq!(a, spec.generate());
    // ids encode (arrival index, tenant): id % T is the tenant/class,
    // id / T the strictly increasing arrival counter
    assert!(a.windows(2).all(|w| w[1].id / 2 == w[0].id / 2 + 1));
    let t1 = a.iter().filter(|r| r.id % 2 == 1).count();
    let t0 = a.len() - t1;
    assert!(t0 > 0 && t1 > 0, "{t0}/{t1}");
    assert!(t1 > 2 * t0, "weight 3 tenant must dominate: {t0}/{t1}");
}

#[test]
fn patience_and_tail_draws_are_seeded_bounded_and_spread() {
    // patience: off means infinitely patient; zero spread means uniform;
    // spread s keeps every draw inside [p/(1+s), p*(1+s)] with real
    // variety across ids, reproducibly
    assert_eq!(patience_for(1, 5, 0.0, 1.0), f64::INFINITY);
    assert_eq!(patience_for(1, 5, 2.5, 0.0), 2.5);
    let draws: Vec<f64> = (0..200).map(|id| patience_for(7, id, 2.0, 1.5)).collect();
    assert_eq!(draws, (0..200).map(|id| patience_for(7, id, 2.0, 1.5)).collect::<Vec<_>>());
    assert!(draws.iter().all(|&p| p >= 2.0 / 2.5 && p <= 2.0 * 2.5), "{draws:?}");
    assert!(draws.iter().any(|&p| p < 1.5) && draws.iter().any(|&p| p > 3.0), "{draws:?}");

    // tail budgets: bounded Pareto over [1, d], seeded, heavy-tailed —
    // mostly short, some near-full draws
    let d = 256usize;
    let budgets: Vec<usize> = (0..2000).map(|id| tail_budget(7, id, d, 1.1)).collect();
    assert_eq!(budgets, (0..2000).map(|id| tail_budget(7, id, d, 1.1)).collect::<Vec<_>>());
    assert!(budgets.iter().all(|&b| (1..=d).contains(&b)));
    let short = budgets.iter().filter(|&&b| b < d / 8).count();
    assert!(2 * short > budgets.len(), "Pareto mass must sit at short lengths: {short}");
    assert!(budgets.iter().any(|&b| b > d / 4), "no long request in 2000 draws");
    assert_eq!(tail_budget(7, 3, 1, 1.1), 1);
    assert_eq!(tail_budget(7, 3, 0, 1.1), 0);
}

#[test]
fn waste_accounting_flags_only_post_abandonment_deliveries() {
    // arrival 0, tokens at 1,2,6,7 with patience 2: the 2->6 gap kills
    // the client at t=4, so exactly the two later deliveries are waste
    let d = [1.0, 2.0, 6.0, 7.0];
    assert_eq!(abandon_time(0.0, &d, 2.0), 4.0);
    assert_eq!(wasted_deliveries(0.0, &d, 2.0), 2);
    // infinitely patient clients never waste
    assert_eq!(wasted_deliveries(0.0, &d, f64::INFINITY), 0);
    // a client that never saw a first token in time wastes everything
    assert_eq!(wasted_deliveries(0.0, &d, 0.5), 4);
}

/// Completions (streams that received their full `budget` of tokens)
/// whose final token was delivered while the client — scored at
/// `patience` — was still listening: the useful-throughput metric.
fn useful_completions(r: &CbReport, seed: u64, patience: f64, budget: usize) -> usize {
    r.streams
        .iter()
        .filter(|(id, s)| {
            s.deliveries.len() == budget
                && *s.deliveries.last().unwrap()
                    <= abandon_time(
                        s.arrival_s,
                        &s.deliveries,
                        patience_for(seed, **id, patience, 0.0),
                    )
        })
        .count()
}

#[test]
fn cancellation_beats_a_blind_engine_on_wasted_tokens_at_useful_throughput() {
    // THE acceptance property. A Markov-bursty overload trace (bursts an
    // order of magnitude over capacity, calm valleys between) drives two
    // engines that differ ONLY in whether the patience sweep can fire:
    // `aware` cancels abandoned requests (freeing their slots and queue
    // positions), `blind` is the armed-but-never-firing baseline the
    // differential tests pin as bit-identical to the legacy path. Scoring
    // both runs' delivery streams against the SAME client patience must
    // show the aware engine wasting strictly fewer decode tokens while
    // completing at least as many still-listening clients.
    let seed = 9u64;
    let patience = 2.5f64;
    let spec = WorkloadSpec {
        seed,
        horizon_s: 20.0,
        process: ArrivalProcess::MarkovBursts {
            lo_rate: 1.0,
            hi_rate: 30.0,
            states: 6,
            dwell_s: 1.0,
        },
        prompts: PromptLengths::Fixed(1024),
        tenant_weights: Vec::new(),
    };
    let arrivals = spec.generate();
    assert!(arrivals.len() > 30, "{}", arrivals.len());
    let base = CbConfig {
        max_slots: 3,
        max_batch: 4,
        decode_tokens: 24,
        seed,
        patience_s: patience,
        ..CbConfig::default()
    };
    // the run horizon leaves 10 s of drain past the last arrival but NOT
    // enough to clear an unbounded backlog — both engines stay saturated,
    // so raw completion counts compare service efficiency, not horizon
    let blind_cfg = CbConfig { patience_s: 1e9, ..base.clone() };
    let aware = engine(base).serve_stream(arrivals.clone(), 30.0);
    let blind = engine(blind_cfg).serve_stream(arrivals, 30.0);

    // the blind engine never cancels; the aware engine did, and both
    // still completed work
    assert_eq!(blind.cancelled, 0, "{blind:?}");
    assert!(aware.cancelled > 0, "the bursts never blew a patience deadline: {aware:?}");
    assert!(aware.completed > 0, "{aware:?}");
    assert!(blind.completed > 0, "{blind:?}");

    // waste, scored identically on both runs: deliveries after the
    // client's abandonment instant under the aware run's patience draws
    let score = |r: &CbReport| -> usize {
        r.streams
            .iter()
            .map(|(id, s)| {
                wasted_deliveries(s.arrival_s, &s.deliveries, patience_for(seed, *id, patience, 0.0))
            })
            .sum()
    };
    let aware_waste = score(&aware);
    let blind_waste = score(&blind);
    assert!(blind_waste > 0, "the blind engine must decode for departed clients");
    assert!(
        aware_waste < blind_waste,
        "cancellation must strictly reduce waste: aware {aware_waste} vs blind {blind_waste}"
    );
    // the engine's own report agrees with the external scoring of it
    assert_eq!(aware.wasted_decode_tokens, aware_waste);

    // ...at equal-or-better useful throughput: completions whose client
    // was still listening at the final token
    let aware_useful = useful_completions(&aware, seed, patience, 24);
    let blind_useful = useful_completions(&blind, seed, patience, 24);
    assert!(
        aware_useful >= blind_useful,
        "cancellation traded useful work away: aware {aware_useful} vs blind {blind_useful}"
    );
    assert!(aware_useful > 0, "nobody useful completed");
    // and without collapsing raw completions either
    assert!(
        2 * aware.completed > blind.completed,
        "aware {} vs blind {}",
        aware.completed,
        blind.completed
    );
}

#[test]
fn heavy_tail_budgets_flow_through_the_engine() {
    // with the tail model on, per-request decode budgets follow the
    // seeded bounded-Pareto draw — completions consume exactly their
    // drawn budget, reproducibly, and the flat-budget anchor (alpha 0)
    // is untouched
    let cfg = CbConfig {
        max_slots: 4,
        max_batch: 4,
        decode_tokens: 64,
        length_tail_alpha: 1.2,
        seed: 11,
        ..CbConfig::default()
    };
    let e = engine(cfg.clone());
    let budgets: Vec<usize> = (1..=20u64).map(|id| e.decode_budget(id)).collect();
    assert!(budgets.iter().all(|&b| (1..=64).contains(&b)), "{budgets:?}");
    assert!(budgets.iter().collect::<std::collections::BTreeSet<_>>().len() > 3, "{budgets:?}");
    assert_eq!(budgets[3], tail_budget(11, 4, 64, 1.2), "engine must delegate to the draw");
    let flat = CbConfig { length_tail_alpha: 0.0, ..cfg };
    assert!((1..=20u64).all(|id| engine(flat.clone()).decode_budget(id) == 64));
}
