//! Differential harness: the live `DecodeSession` path and the pure
//! cost-model backend must make IDENTICAL scheduling decisions — admission
//! order, batch composition, slot occupancy per decode step, evictions,
//! completions — on fixed-seed traces. Both run the same scheduler loop
//! (`CbEngine::serve_stream_with`), so any divergence means the live
//! plumbing (session lifecycle, KV accounting, variable-length prefill)
//! broke; any KV violation means the modeled admission gate and the real
//! session memory disagree.
//!
//! Runs entirely on an in-memory synthetic decoder bundle — no artifacts,
//! no PJRT — so it executes everywhere (CI included).

use std::collections::BTreeSet;

use astra::comm::trace::BandwidthTrace;
use astra::config::RunConfig;
use astra::coordinator::Cluster;
use astra::model::shape::VqSetting;
use astra::model::TransformerShape;
use astra::server::cluster::{ClusterEngine, RouteKind};
use astra::server::live::{live_arrivals, live_engine, serve_live, LiveBackend, LiveReport};
use astra::server::policy::PolicyKind;
use astra::server::scheduler::{CbConfig, CbEvent, CbReport, ModelBackend};
use astra::server::Request;
use astra::sim::latency::SimParams;
use astra::util::rng::Rng;

fn tiny_cluster(n_devices: usize, seed: u64) -> Cluster {
    let shape = TransformerShape {
        n_layers: 2,
        d_model: 16,
        n_heads: 2,
        d_ff: 32,
        seq_len: 8 * n_devices,
        elem_bytes: 4,
    };
    let config = RunConfig { n_devices, ..RunConfig::default() };
    Cluster::synthetic_decoder(&shape, 32, VqSetting::new(2, 8), config, seed).unwrap()
}

fn params() -> SimParams {
    SimParams::paper_encoder()
}

fn trace() -> BandwidthTrace {
    BandwidthTrace::constant(100.0, 1e9)
}

/// Run the same arrivals through the cost-model backend and the live
/// backend; both rides on the identical scheduler loop and virtual clock.
fn run_pair(
    cluster: &Cluster,
    cfg: &CbConfig,
    arrivals: &[Request],
    horizon: f64,
) -> (CbReport, LiveReport) {
    let mut model = live_engine(cluster, cfg.clone(), params(), trace());
    let m = model
        .serve_stream_with(&mut ModelBackend, arrivals.to_vec(), horizon)
        .unwrap();
    let live = serve_live(cluster, cfg.clone(), params(), trace(), arrivals.to_vec(), horizon)
        .unwrap();
    (m, live)
}

fn assert_agree(m: &CbReport, live: &LiveReport, label: &str) {
    assert_eq!(m.events, live.report.events, "{label}: decision streams diverged");
    assert_eq!(m.completed, live.report.completed, "{label}");
    assert_eq!(m.censored, live.report.censored, "{label}");
    assert_eq!(m.kv_rejected, live.report.kv_rejected, "{label}");
    assert_eq!(m.kv_evictions, live.report.kv_evictions, "{label}");
    assert_eq!(m.kv_peak_bytes, live.report.kv_peak_bytes, "{label}");
    assert_eq!(m.prefix_hits, live.report.prefix_hits, "{label}");
    assert_eq!(m.prefix_hit_tokens, live.report.prefix_hit_tokens, "{label}");
    assert_eq!(m.swap_outs, live.report.swap_outs, "{label}");
    assert_eq!(m.swap_ins, live.report.swap_ins, "{label}");
    assert_eq!(m.swap_bytes, live.report.swap_bytes, "{label}");
    assert_eq!(m.slo_preemptions, live.report.slo_preemptions, "{label}");
    assert_eq!(m.classes.len(), live.report.classes.len(), "{label}");
    // the client model is part of the decision stream: identical
    // cancellations, waste accounting, and per-token delivery timestamps
    assert_eq!(m.cancelled, live.report.cancelled, "{label}");
    assert_eq!(m.wasted_decode_tokens, live.report.wasted_decode_tokens, "{label}");
    assert_eq!(m.streams, live.report.streams, "{label}: delivery timestamps diverged");
    // the live sessions' real memory never contradicted the model's gate
    assert_eq!(live.report.kv_violations, 0, "{label}");
}

#[test]
fn live_and_model_agree_on_three_fixed_seed_traces() {
    let cluster = tiny_cluster(2, 3);
    let seq = cluster.artifact.meta.seq_len;
    // three distinct regimes: light load, saturating load, KV-capped
    let base = CbConfig { max_slots: 4, max_batch: 4, decode_tokens: 6, ..CbConfig::default() };
    let capped = {
        let probe = live_engine(&cluster, base.clone(), params(), trace());
        CbConfig { kv_cap_bytes: 2 * probe.kv_projection(seq), ..base.clone() }
    };
    let traces: [(u64, f64, &CbConfig); 3] =
        [(11, 4.0, &base), (22, 40.0, &base), (33, 25.0, &capped)];
    for (seed, rate, cfg) in traces {
        let arrivals = live_arrivals(&mut Rng::new(seed), rate, 4.0, seq);
        assert!(arrivals.len() > 2, "seed {seed} produced {} arrivals", arrivals.len());
        let (m, live) = run_pair(&cluster, cfg, &arrivals, 1e4);
        let label = format!("seed {seed} rate {rate}");
        assert_agree(&m, &live, &label);
        // decisions happened: every admitted request decoded its budget
        assert!(m.completed > 0, "{label}");
        let steps: usize = m
            .events
            .iter()
            .map(|e| match e {
                CbEvent::Decode { ids } => ids.len(),
                _ => 0,
            })
            .sum();
        assert!(steps >= m.completed * cfg.decode_tokens, "{label}: {steps}");
        // the live run produced real full-length generations for each
        // completion, within vocab
        let vocab = cluster.artifact.meta.vocab_size;
        let full = live
            .generations
            .iter()
            .filter(|(_, toks)| toks.len() == cfg.decode_tokens)
            .count();
        assert_eq!(full, m.completed, "{label}");
        for (_, toks) in &live.generations {
            assert!(toks.iter().all(|&t| t < vocab), "{label}");
        }
    }
}

#[test]
fn live_and_model_agree_with_chunked_prefill() {
    // chunked prefill (PrefillChunk events, deferred live replay via
    // DecodeSession::replay_range) must keep the differential exact on
    // fixed-seed traces — including under KV pressure, where prefilling
    // slots are evicted mid-replay and rebuilt from scratch
    let cluster = tiny_cluster(2, 5);
    let seq = cluster.artifact.meta.seq_len;
    let base = CbConfig {
        max_slots: 4,
        max_batch: 4,
        decode_tokens: 6,
        prefill_chunk_tokens: 5,
        ..CbConfig::default()
    };
    let capped = {
        let probe = live_engine(&cluster, base.clone(), params(), trace());
        CbConfig { kv_cap_bytes: 2 * probe.kv_projection(seq), ..base.clone() }
    };
    let traces: [(u64, f64, &CbConfig); 3] =
        [(44, 6.0, &base), (55, 40.0, &base), (66, 25.0, &capped)];
    for (seed, rate, cfg) in traces {
        let arrivals = live_arrivals(&mut Rng::new(seed), rate, 4.0, seq);
        assert!(arrivals.len() > 2, "seed {seed} produced {} arrivals", arrivals.len());
        let (m, live) = run_pair(&cluster, cfg, &arrivals, 1e4);
        let label = format!("chunked seed {seed} rate {rate}");
        assert_agree(&m, &live, &label);
        assert_eq!(m.prefill_chunks, live.report.prefill_chunks, "{label}");
        assert!(m.prefill_chunks > 0, "{label}: no chunks on prompts > budget");
        assert!(
            m.events.iter().any(|e| matches!(e, CbEvent::PrefillChunk { .. })),
            "{label}"
        );
        assert!(m.completed > 0, "{label}");
        // real full-length generations for every completion
        let full = live
            .generations
            .iter()
            .filter(|(_, toks)| toks.len() == cfg.decode_tokens)
            .count();
        assert_eq!(full, m.completed, "{label}");
    }

    // chunking must not change what any request decodes — only when:
    // the same trace unchunked yields identical (sorted) generations
    let arrivals = live_arrivals(&mut Rng::new(44), 6.0, 4.0, seq);
    let (_, live_chunked) = run_pair(&cluster, &base, &arrivals, 1e4);
    let unchunked = CbConfig { prefill_chunk_tokens: 0, ..base };
    let (_, live_plain) = run_pair(&cluster, &unchunked, &arrivals, 1e4);
    let mut a = live_chunked.generations.clone();
    let mut b = live_plain.generations.clone();
    a.sort();
    b.sort();
    assert_eq!(a, b, "chunked replay changed greedy generations");
}

#[test]
fn live_and_model_agree_on_shared_prefix_traces() {
    // the prefix-cache differential: grouped prompts share block-aligned
    // prefixes, the model's radix decisions (PrefixHit, suffix-only
    // replay, block-store registration/reclaim) must be executed exactly
    // by the live backend on fixed-seed traces — plain, chunked, and
    // KV-capped — and the dedup'd live bytes must never contradict the
    // pool's gate
    let cluster = tiny_cluster(2, 13);
    let seq = cluster.artifact.meta.seq_len;
    let base = CbConfig {
        max_slots: 4,
        max_batch: 4,
        decode_tokens: 6,
        prefix_cache: true,
        kv_block_tokens: 4,
        prompt_groups: 2,
        ..CbConfig::default()
    };
    let chunked = CbConfig { prefill_chunk_tokens: 5, ..base.clone() };
    let capped = {
        let probe = live_engine(&cluster, base.clone(), params(), trace());
        CbConfig { kv_cap_bytes: 2 * probe.kv_projection(seq), ..base.clone() }
    };
    for (seed, rate, cfg) in
        [(101u64, 8.0, &base), (102, 30.0, &chunked), (103, 25.0, &capped)]
    {
        let arrivals = live_arrivals(&mut Rng::new(seed), rate, 4.0, seq);
        assert!(arrivals.len() > 3, "seed {seed} produced {} arrivals", arrivals.len());
        let (m, live) = run_pair(&cluster, cfg, &arrivals, 1e4);
        let label = format!("prefix seed {seed} rate {rate}");
        assert_agree(&m, &live, &label);
        assert!(m.prefix_hits > 0, "{label}: grouped prompts never shared a block");
        assert!(
            m.events.iter().any(|e| matches!(e, CbEvent::PrefixHit { .. })),
            "{label}"
        );
        // hits are block-aligned and bounded by what was admitted
        assert_eq!(m.prefix_hit_tokens % 4, 0, "{label}");
        assert!(m.prefix_hit_rate() > 0.0, "{label}");
        assert!(m.prefix_hit_rate() <= 1.0, "{label}");
        assert!(m.completed > 0, "{label}");
        // real full-length generations for every completion
        let full = live
            .generations
            .iter()
            .filter(|(_, toks)| toks.len() == cfg.decode_tokens)
            .count();
        assert_eq!(full, m.completed, "{label}");
    }

    // suffix-only replay must not change a single generated token. The
    // control keeps positional locality (the prefix-cache row-selection
    // rule) but disables sharing via an oversized block, so every prompt
    // replays in full: same cache contents per request, different
    // schedule, identical generations. (A prefix-OFF run is NOT a valid
    // control — classic locality scales with prompt length and holds
    // different rows in full precision, legitimately changing logits.)
    let arrivals = live_arrivals(&mut Rng::new(101), 8.0, 4.0, seq);
    let (_, live_on) = run_pair(&cluster, &base, &arrivals, 1e4);
    let nohits = CbConfig { kv_block_tokens: seq + 1, ..base.clone() };
    let (m_nohits, live_nohits) = run_pair(&cluster, &nohits, &arrivals, 1e4);
    assert_eq!(m_nohits.prefix_hits, 0, "oversized blocks must never share");
    let mut a = live_on.generations.clone();
    let mut b = live_nohits.generations.clone();
    a.sort();
    b.sort();
    assert_eq!(a, b, "prefix attach changed greedy generations");
    // and the cached run is reproducible bit for bit
    let (_, live_again) = run_pair(&cluster, &base, &arrivals, 1e4);
    assert_eq!(live_again.report.events, live_on.report.events);
    assert_eq!(live_again.generations, live_on.generations);
}

#[test]
fn live_and_model_agree_on_swap_thrash_trace() {
    // the swap differential: a tight cap + long decode budgets force
    // preemption every few iterations, and a fast host link makes the
    // priced transfer beat recompute — sessions move to the host tier and
    // back (SwapOut/SwapIn events) with decode progress preserved, on
    // both backends identically
    let cluster = tiny_cluster(2, 17);
    let seq = cluster.artifact.meta.seq_len;
    let base = CbConfig {
        max_slots: 4,
        max_batch: 4,
        decode_tokens: 3 * seq,
        swap_bandwidth_mbps: 1e5,
        swap_latency_s: 1e-4,
        ..CbConfig::default()
    };
    let probe = live_engine(&cluster, base.clone(), params(), trace());
    let capped = CbConfig { kv_cap_bytes: 2 * probe.kv_projection(seq), ..base.clone() };
    let arrivals: Vec<Request> =
        (1..=4u64).map(|id| Request { id, arrival_s: 0.0, tokens: seq }).collect();
    let (m, live) = run_pair(&cluster, &capped, &arrivals, 1e5);
    assert_agree(&m, &live, "swap thrash");
    assert!(m.swap_outs > 0, "pressure must swap on the fast link: {m:?}");
    assert_eq!(m.swap_outs, m.swap_ins, "everything swapped back in: {m:?}");
    assert!(m.swap_bytes > 0);
    assert!(m.events.iter().any(|e| matches!(e, CbEvent::SwapOut { .. })));
    assert!(m.events.iter().any(|e| matches!(e, CbEvent::SwapIn { .. })));
    assert_eq!(m.completed, 4, "{m:?}");
    // swap preserves decode progress: every request generates its full
    // budget, and the token sequences equal the recompute-preemption run
    // (greedy decode is deterministic either way)
    for (id, toks) in &live.generations {
        assert_eq!(toks.len(), 3 * seq, "request {id}");
    }
    let recompute = CbConfig { swap_bandwidth_mbps: 0.0, ..capped.clone() };
    let (m_rec, live_rec) = run_pair(&cluster, &recompute, &arrivals, 1e5);
    assert!(m_rec.kv_evictions > 0, "recompute control must evict: {m_rec:?}");
    assert_eq!(m_rec.swap_outs, 0);
    let mut a = live.generations.clone();
    let mut b = live_rec.generations.clone();
    a.sort();
    b.sort();
    assert_eq!(a, b, "swap changed what a request decodes");
    // the swapped schedule wastes no decode work: it takes exactly the
    // budget in steps, while recompute regenerates evicted progress
    let steps = |r: &CbReport| -> usize {
        r.events
            .iter()
            .map(|e| match e {
                CbEvent::Decode { ids } => ids.len(),
                _ => 0,
            })
            .sum()
    };
    assert_eq!(steps(&m), 4 * 3 * seq, "{m:?}");
    assert!(steps(&m_rec) > 4 * 3 * seq, "{}", steps(&m_rec));
}

#[test]
fn live_and_model_agree_under_all_scheduling_policies() {
    // the policy layer makes decisions in the shared loop, so every
    // policy must keep the differential exact: prefix-aware admission
    // reordering over grouped prompts under a cap, and slo-class
    // ordering + class-based victim selection + the proactive hook on a
    // pressure trace — live and cost-model streams identical throughout
    let cluster = tiny_cluster(2, 21);
    let seq = cluster.artifact.meta.seq_len;
    let base = CbConfig { max_slots: 4, max_batch: 4, decode_tokens: 6, ..CbConfig::default() };

    // prefix-aware: warm requests jump cold ones while blocks are hot
    let aware = {
        let proto = CbConfig {
            policy: PolicyKind::PrefixAware,
            prefix_cache: true,
            kv_block_tokens: 4,
            prompt_groups: 2,
            ..base.clone()
        };
        let probe = live_engine(&cluster, proto.clone(), params(), trace());
        CbConfig { kv_cap_bytes: 2 * probe.kv_projection(seq), ..proto }
    };
    let arrivals = live_arrivals(&mut Rng::new(201), 25.0, 4.0, seq);
    assert!(arrivals.len() > 3, "{}", arrivals.len());
    let (m, live) = run_pair(&cluster, &aware, &arrivals, 1e4);
    assert_agree(&m, &live, "prefix-aware policy");
    assert!(m.completed > 0);
    assert!(m.prefix_hits > 0, "grouped prompts must share under the reordering policy");

    // slo-class: long decode budgets under a tight cap force victim
    // selection; the tight high-class deadline arms the proactive hook
    let slo = {
        let proto = CbConfig {
            policy: PolicyKind::SloClass,
            classes: vec![50.0, 0.3],
            decode_tokens: 3 * seq,
            ..base.clone()
        };
        let probe = live_engine(&cluster, proto.clone(), params(), trace());
        CbConfig { kv_cap_bytes: 2 * probe.kv_projection(seq), ..proto }
    };
    let burst: Vec<Request> =
        (1..=6u64).map(|id| Request { id, arrival_s: 0.0, tokens: seq }).collect();
    let (m, live) = run_pair(&cluster, &slo, &burst, 1e5);
    assert_agree(&m, &live, "slo-class policy");
    assert_eq!(m.completed, 6, "{m:?}");
    assert!(m.kv_evictions + m.swap_outs > 0, "pressure trace must preempt: {m:?}");
    assert_eq!(m.classes.len(), 2);
    // real full-length generations for every completion, class-tagged
    for (id, toks) in &live.generations {
        assert_eq!(toks.len(), 3 * seq, "request {id}");
    }
}

#[test]
fn fleet_live_and_model_agree_across_a_mid_trace_drain() {
    // the 2-replica differential: one fixed-seed arrival stream routed
    // across two replicas, replica 0 drained mid-trace (slots evicted,
    // queue spilled to the survivor through the router) — the live and
    // cost-model fleets must emit identical replica-tagged decision
    // streams, and the drain must lose and double-complete nobody
    let cluster = tiny_cluster(2, 25);
    let seq = cluster.artifact.meta.seq_len;
    let cfg = CbConfig {
        max_slots: 4,
        max_batch: 4,
        decode_tokens: 6,
        prefix_cache: true,
        kv_block_tokens: 4,
        prompt_groups: 2,
        ..CbConfig::default()
    };
    let arrivals = live_arrivals(&mut Rng::new(301), 25.0, 4.0, seq);
    assert!(arrivals.len() > 3, "{}", arrivals.len());
    let n = arrivals.len();
    // live_engine pins the trace-shaping knobs (seed, prompt vocab); the
    // live backends must see the same pinned config
    let pinned = live_engine(&cluster, cfg.clone(), params(), trace()).cfg;
    let mk_fleet = || {
        let engines: Vec<_> =
            (0..2).map(|_| live_engine(&cluster, cfg.clone(), params(), trace())).collect();
        ClusterEngine::new(engines, RouteKind::RoundRobin).with_drain(0, 2.0)
    };
    let m = mk_fleet().serve_stream(arrivals.clone(), 1e4).unwrap();
    let mut backends: Vec<LiveBackend> =
        (0..2).map(|_| LiveBackend::for_config(&cluster, &pinned)).collect();
    let l = mk_fleet().serve_stream_with(&mut backends, arrivals, 1e4).unwrap();
    assert_eq!(m.events, l.events, "fleet decision streams diverged");
    assert_eq!(m.drained, Some(0));
    assert_eq!(l.drained, Some(0));
    for (mr, lr) in m.replicas.iter().zip(&l.replicas) {
        assert_eq!(mr.completed, lr.completed);
        assert_eq!(mr.censored, lr.censored);
        assert_eq!(mr.kv_rejected, lr.kv_rejected);
        assert_eq!(mr.prefix_hits, lr.prefix_hits);
        assert_eq!(mr.swap_outs, lr.swap_outs);
        // the survivor's real session memory never contradicted the gate
        assert_eq!(lr.kv_violations, 0);
    }
    // nobody is lost or double-completed across the drain
    let mut seen = BTreeSet::new();
    for e in &m.events {
        if let CbEvent::Complete { id } = e.event {
            assert!(seen.insert(id), "request {id} completed twice");
        }
    }
    assert_eq!(m.completed(), n, "a request was lost across the drain");
    assert_eq!(m.censored(), 0);
    // both replicas actually participated: the victim emitted events
    // before its removal, the survivor finished the fleet's work
    assert!(m.events.iter().any(|e| e.replica == 0));
    assert!(m.replicas[1].completed > 0);
}

#[test]
fn live_and_model_agree_with_impatient_clients() {
    // the streaming-client differential: saturating load over a small
    // slot count makes queue waits blow past patience deadlines, so
    // requests are cancelled mid-run (Cancelled events, slots and blocks
    // freed) — and the live path must make the identical cancellation
    // decisions, free the identical sessions, and record the identical
    // per-token delivery timestamps
    let cluster = tiny_cluster(2, 31);
    let seq = cluster.artifact.meta.seq_len;
    let cfg = CbConfig {
        max_slots: 2,
        max_batch: 2,
        decode_tokens: 8,
        patience_s: 5.0,
        patience_spread: 1.0,
        ..CbConfig::default()
    };
    let arrivals = live_arrivals(&mut Rng::new(501), 40.0, 4.0, seq);
    assert!(arrivals.len() > 10, "{}", arrivals.len());
    let (m, live) = run_pair(&cluster, &cfg, &arrivals, 1e4);
    assert_agree(&m, &live, "impatient clients");
    assert!(m.cancelled > 0, "saturation must cancel someone: {m:?}");
    assert!(m.completed > 0, "patient early arrivals must still finish: {m:?}");
    assert!(!m.streams.is_empty(), "patience on must record delivery streams");
    assert!(!m.time_to_token.is_empty());
    // cancellation is terminal: each Cancelled id appears once and never
    // completes, and cancelled requests never enter the live generations
    let mut cancelled = BTreeSet::new();
    let mut completed = BTreeSet::new();
    for e in &m.events {
        match e {
            CbEvent::Cancelled { id } => {
                assert!(cancelled.insert(*id), "request {id} cancelled twice")
            }
            CbEvent::Complete { id } => {
                completed.insert(*id);
            }
            _ => {}
        }
    }
    assert_eq!(cancelled.len(), m.cancelled);
    assert!(cancelled.is_disjoint(&completed), "a cancelled request completed");
    for id in &cancelled {
        assert!(!live.generations.contains_key(id), "cancelled {id} kept a generation");
    }

    // the zero-cancellation anchor: patience off is the legacy code path,
    // and an armed-but-never-firing patience (huge finite deadline) must
    // reproduce its event stream bit for bit — recording delivery
    // timestamps without perturbing a single decision
    let off = CbConfig { patience_s: 0.0, patience_spread: 0.0, ..cfg.clone() };
    let arrivals = live_arrivals(&mut Rng::new(501), 40.0, 4.0, seq);
    let (m_off, live_off) = run_pair(&cluster, &off, &arrivals, 1e4);
    let huge = CbConfig { patience_s: 1e9, ..cfg.clone() };
    let (m_huge, live_huge) = run_pair(&cluster, &huge, &arrivals, 1e4);
    assert_eq!(m_off.events, m_huge.events, "an unfired patience sweep changed decisions");
    assert_eq!(live_off.report.events, live_huge.report.events);
    assert_eq!(live_off.generations, live_huge.generations);
    assert_eq!(m_huge.cancelled, 0);
    assert_eq!(m_huge.wasted_decode_tokens, 0, "infinite-patience clients waste nothing");
    assert!(!m_huge.streams.is_empty(), "armed patience must record streams");
    assert!(m_off.streams.is_empty(), "patience off must not record streams");
}

#[test]
fn serial_decode_escape_hatch_is_bit_identical_to_batched() {
    // --serial-decode only changes how the live backend executes a
    // StepBatch (one session at a time vs one fused batched GEMM per
    // layer); the scheduler never reads the flag, so the decision stream
    // is identical by construction and every generated token must match —
    // across the plain, chunked, and prefix-cache regimes
    let cluster = tiny_cluster(2, 29);
    let seq = cluster.artifact.meta.seq_len;
    let base = CbConfig { max_slots: 4, max_batch: 4, decode_tokens: 6, ..CbConfig::default() };
    let chunked = CbConfig { prefill_chunk_tokens: 5, ..base.clone() };
    let prefixed = CbConfig {
        prefix_cache: true,
        kv_block_tokens: 4,
        prompt_groups: 2,
        ..base.clone()
    };
    for (label, cfg) in
        [("plain", &base), ("chunked", &chunked), ("prefix", &prefixed)]
    {
        let arrivals = live_arrivals(&mut Rng::new(401), 25.0, 4.0, seq);
        assert!(arrivals.len() > 3, "{label}: {}", arrivals.len());
        let (m, batched) = run_pair(&cluster, cfg, &arrivals, 1e4);
        let serial_cfg = CbConfig { serial_decode: true, ..cfg.clone() };
        let (m_serial, serial) = run_pair(&cluster, &serial_cfg, &arrivals, 1e4);
        assert_agree(&m, &batched, label);
        assert_agree(&m_serial, &serial, label);
        assert_eq!(m.events, m_serial.events, "{label}: serial flag leaked into scheduling");
        assert_eq!(
            batched.report.events, serial.report.events,
            "{label}: event streams diverged"
        );
        assert_eq!(
            batched.generations, serial.generations,
            "{label}: batched decode changed a generated token"
        );
        assert_eq!(batched.live_steps, serial.live_steps, "{label}");
        assert!(m.completed > 0, "{label}");
        // decode batches of size >= 2 actually ran fused
        assert!(
            m.events.iter().any(|e| matches!(e, CbEvent::Decode { ids } if ids.len() >= 2)),
            "{label}: no multi-slot decode batch in the trace"
        );
    }
}

#[test]
fn kv_capped_run_admits_later_but_loses_no_one() {
    // the cap reshapes the schedule (different decision stream, deferred
    // admissions) without dropping feasible work — and the live path
    // tracks the reshaped schedule exactly
    let cluster = tiny_cluster(2, 7);
    let seq = cluster.artifact.meta.seq_len;
    let base = CbConfig { max_slots: 4, max_batch: 4, decode_tokens: 8, ..CbConfig::default() };
    let probe = live_engine(&cluster, base.clone(), params(), trace());
    let cap = 2 * probe.kv_projection(seq) + probe.kv_step_bytes();
    let capped = CbConfig { kv_cap_bytes: cap, ..base.clone() };
    let arrivals: Vec<Request> =
        (1..=6u64).map(|id| Request { id, arrival_s: 0.0, tokens: seq }).collect();

    let (m_open, live_open) = run_pair(&cluster, &base, &arrivals, 1e4);
    let (m_capped, live_capped) = run_pair(&cluster, &capped, &arrivals, 1e4);
    assert_agree(&m_open, &live_open, "open");
    assert_agree(&m_capped, &live_capped, "capped");

    // both finish everyone, but the cap forces a different schedule
    assert_eq!(m_open.completed, 6);
    assert_eq!(m_capped.completed, 6);
    assert_ne!(m_open.events, m_capped.events);
    assert!(m_capped.kv_peak_bytes <= cap);
    assert!(m_open.kv_peak_bytes > cap, "{} <= {cap}", m_open.kv_peak_bytes);

    // identical greedy generations either way: scheduling must not change
    // what a request decodes, only when
    let mut a = live_open.generations.clone();
    let mut b = live_capped.generations.clone();
    a.sort();
    b.sort();
    assert_eq!(a, b);
}

#[test]
fn eviction_recompute_matches_model_and_preserves_generations() {
    // force mid-decode evictions: prompts are cheap, growth is not
    let cluster = tiny_cluster(2, 9);
    let seq = cluster.artifact.meta.seq_len;
    let base =
        CbConfig { max_slots: 4, max_batch: 4, decode_tokens: 3 * seq, ..CbConfig::default() };
    let probe = live_engine(&cluster, base.clone(), params(), trace());
    assert!(4 * probe.kv_slot_bytes(seq, 0) <= 2 * probe.kv_projection(seq));
    let capped = CbConfig { kv_cap_bytes: 2 * probe.kv_projection(seq), ..base };
    let arrivals: Vec<Request> =
        (1..=4u64).map(|id| Request { id, arrival_s: 0.0, tokens: seq }).collect();
    let (m, live) = run_pair(&cluster, &capped, &arrivals, 1e5);
    assert_agree(&m, &live, "eviction");
    assert!(m.kv_evictions > 0, "pressure must evict: {m:?}");
    assert_eq!(m.completed, 4, "{m:?}");
    // recompute preemption: evicted-and-readmitted requests still produce
    // their full deterministic generations
    for (id, toks) in &live.generations {
        assert_eq!(toks.len(), 3 * seq, "request {id}");
    }
}

#[test]
fn heterogeneous_fleet_with_replanning_keeps_the_differential_exact() {
    // profile-weighted pricing + online re-planning: both backends must
    // still make identical decisions — including any CbEvent::Replan the
    // EWMA planner emits (both sample the same shared bandwidth trace) —
    // and the live sessions' proportional prompt splits must never
    // contradict the modeled KV gate
    let cluster = tiny_cluster(4, 7);
    let seq = cluster.artifact.meta.seq_len;
    let cfg = CbConfig {
        max_slots: 4,
        max_batch: 4,
        decode_tokens: 6,
        device_speeds: vec![4.0, 2.0, 1.0, 0.5],
        replan_every_s: 5.0,
        ..CbConfig::default()
    };
    let tr = BandwidthTrace::markovian(&mut Rng::new(7), 20.0, 100.0, 9, 1.0, 600.0);
    let arrivals = live_arrivals(&mut Rng::new(44), 12.0, 30.0, seq);
    assert!(arrivals.len() > 4, "{} arrivals", arrivals.len());
    let mut model = live_engine(&cluster, cfg.clone(), params(), tr.clone());
    let m = model.serve_stream_with(&mut ModelBackend, arrivals.clone(), 1e4).unwrap();
    let live = serve_live(&cluster, cfg.clone(), params(), tr, arrivals, 1e4).unwrap();
    assert_agree(&m, &live, "hetero replan");
    assert_eq!(m.replans, live.report.replans, "replan counters diverged");
    assert!(m.completed > 0);
    // every completion still decodes its full budget on the live path
    let full = live
        .generations
        .iter()
        .filter(|(_, toks)| toks.len() == cfg.decode_tokens)
        .count();
    assert_eq!(full, m.completed);
}

#[test]
fn all_equal_device_speeds_reproduce_the_unprofiled_streams_bit_for_bit() {
    // `--device-speeds 2,2,2,2` must be indistinguishable from no flag at
    // all: an all-equal profile collapses to None, so pricing, events,
    // and generations are the legacy static streams
    let cluster = tiny_cluster(4, 5);
    let seq = cluster.artifact.meta.seq_len;
    let base = CbConfig { max_slots: 4, max_batch: 4, decode_tokens: 6, ..CbConfig::default() };
    let flagged = CbConfig {
        device_speeds: vec![2.0, 2.0, 2.0, 2.0],
        replan_every_s: 5.0,
        ..base.clone()
    };
    let arrivals = live_arrivals(&mut Rng::new(31), 20.0, 8.0, seq);
    let (m_base, live_base) = run_pair(&cluster, &base, &arrivals, 1e4);
    let (m_flag, live_flag) = run_pair(&cluster, &flagged, &arrivals, 1e4);
    assert_eq!(m_base.events, m_flag.events, "uniform profile changed the model stream");
    assert_eq!(m_flag.replans, 0, "uniform profile must never re-plan");
    let mut a = live_base.generations.clone();
    let mut b = live_flag.generations.clone();
    a.sort();
    b.sort();
    assert_eq!(a, b, "uniform profile changed live generations");
}
