//! Acceptance tests for straggler-free heterogeneous decode: on a skewed
//! fleet, profile-weighted partitioning (and online re-planning on top)
//! must beat the even-split static engine on both completed requests and
//! p95 latency — and with the profile off or uniform, everything must
//! collapse to the legacy streams bit for bit. All cost-model runs on
//! fixed seeds: deterministic everywhere, CI included.

use astra::comm::trace::BandwidthTrace;
use astra::model::shape::{TransformerShape, VqSetting};
use astra::parallel::strategies::{Strategy, StrategyKind};
use astra::server::scheduler::{CbConfig, CbEngine, CbEvent, CbReport};
use astra::sim::latency::SimParams;
use astra::util::rng::Rng;

fn engine(trace: BandwidthTrace, cfg: CbConfig) -> CbEngine {
    CbEngine::new(
        TransformerShape::paper_encoder(1024),
        Strategy::new(StrategyKind::Astra { vq: VqSetting::new(16, 1024) }, 4),
        SimParams::paper_encoder(),
        trace,
        cfg,
    )
}

/// The paper-style 600 s Markov bandwidth trace (Appendix E parameters).
fn markov600() -> BandwidthTrace {
    BandwidthTrace::markovian(&mut Rng::new(7), 20.0, 100.0, 9, 1.0, 600.0)
}

fn serve(cfg: CbConfig) -> CbReport {
    engine(markov600(), cfg).serve_poisson(&mut Rng::new(13), 12.0, 600.0)
}

#[test]
fn profile_weighted_replanning_beats_even_split_static_on_a_skewed_fleet() {
    // the headline acceptance: on a 4.0/2.0/1.0/0.5 fleet under the
    // 600 s Markov trace, the profile-weighted engine — with and without
    // online re-planning — completes MORE requests at a LOWER p95 than
    // the even-split static engine serving the same arrivals
    let base = CbConfig::default();
    let skewed = CbConfig { device_speeds: vec![4.0, 2.0, 1.0, 0.5], ..CbConfig::default() };
    let replanned = CbConfig { replan_every_s: 5.0, ..skewed.clone() };

    let mut even = serve(base);
    let mut hetero = serve(skewed);
    let mut hetero_replan = serve(replanned);

    assert!(even.completed > 0, "baseline served nothing");
    assert!(
        hetero.completed > even.completed,
        "static profile-weighted did not beat even-split: {} vs {}",
        hetero.completed,
        even.completed
    );
    assert!(
        hetero.latency.p95() < even.latency.p95(),
        "static profile-weighted p95 did not improve: {} vs {}",
        hetero.latency.p95(),
        even.latency.p95()
    );
    assert!(
        hetero_replan.completed > even.completed,
        "re-planned did not beat even-split on completed: {} vs {}",
        hetero_replan.completed,
        even.completed
    );
    assert!(
        hetero_replan.latency.p95() < even.latency.p95(),
        "re-planned p95 did not improve: {} vs {}",
        hetero_replan.latency.p95(),
        even.latency.p95()
    );
    // the static run never re-plans by construction; the re-planned
    // run's swaps (if any) are all recorded as Replan events
    assert_eq!(hetero.replans, 0);
    let replan_events =
        hetero_replan.events.iter().filter(|e| matches!(e, CbEvent::Replan { .. })).count();
    assert_eq!(replan_events, hetero_replan.replans);
    // and neither heterogeneous run ever violated KV accounting
    assert_eq!(hetero.kv_violations, 0);
    assert_eq!(hetero_replan.kv_violations, 0);
}

#[test]
fn replan_every_zero_pins_the_initial_plan() {
    // `--replan-every 0` on a skewed fleet IS the static
    // profile-weighted engine: same events, same totals, zero re-plans
    let skewed = CbConfig { device_speeds: vec![4.0, 2.0, 1.0, 0.5], ..CbConfig::default() };
    let pinned = CbConfig { replan_every_s: 0.0, ..skewed.clone() };
    let a = serve(skewed);
    let b = serve(pinned);
    assert_eq!(a.events, b.events);
    assert_eq!(a.completed, b.completed);
    assert_eq!(a.replans, 0);
    assert_eq!(b.replans, 0);
}

#[test]
fn uniform_speeds_reproduce_the_legacy_engine_bit_for_bit() {
    // seeded sweep: all-equal --device-speeds (any value) and no flag at
    // all price identically — the engine-level anchor for the schedule
    // builders' is_uniform() delegation
    let mut rng = Rng::new(29);
    for case in 0..5 {
        let seed = rng.below(1000) as u64;
        let rate = 4.0 + rng.f64() * 12.0;
        let speed = 0.5 + rng.f64() * 4.0;
        let run = |cfg: CbConfig| {
            engine(BandwidthTrace::constant(100.0, 1e9), cfg).serve_poisson(
                &mut Rng::new(seed),
                rate,
                60.0,
            )
        };
        let mut plain = run(CbConfig::default());
        let mut flagged = run(CbConfig {
            device_speeds: vec![speed; 4],
            replan_every_s: 5.0,
            ..CbConfig::default()
        });
        assert_eq!(
            plain.events, flagged.events,
            "case {case}: uniform speed {speed} changed the stream"
        );
        assert_eq!(plain.completed, flagged.completed, "case {case}");
        assert_eq!(flagged.replans, 0, "case {case}: uniform fleet re-planned");
        // latencies too, not just decisions: the Summary sketches are
        // built from identical samples
        assert_eq!(plain.latency.p95(), flagged.latency.p95(), "case {case}");
        assert_eq!(plain.ttft.p50(), flagged.ttft.p50(), "case {case}");
    }
}
