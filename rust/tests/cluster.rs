//! Cluster-loop integration tests: the multi-replica event loop must
//! degenerate to the single engine bit for bit, never lose (or
//! double-complete) a request across a replica drain, keep the fleet
//! rollups exact sums of the per-replica reports, and make
//! prefix-affinity routing actually buy cache hits over round-robin.

use std::collections::BTreeSet;

use astra::comm::trace::BandwidthTrace;
use astra::model::shape::{TransformerShape, VqSetting};
use astra::parallel::strategies::{Strategy, StrategyKind};
use astra::server::batcher::poisson_arrivals;
use astra::server::cluster::{ClusterEngine, RouteKind};
use astra::server::scheduler::{CbConfig, CbEngine, CbEvent};
use astra::server::Request;
use astra::sim::latency::SimParams;
use astra::util::rng::Rng;

fn engine(cfg: CbConfig) -> CbEngine {
    CbEngine::new(
        TransformerShape::paper_encoder(1024),
        Strategy::new(StrategyKind::Astra { vq: VqSetting::new(16, 1024) }, 4),
        SimParams::paper_encoder(),
        BandwidthTrace::constant(100.0, 1e9),
        cfg,
    )
}

#[test]
fn single_replica_fleet_reproduces_the_engine_bit_for_bit() {
    // --replicas 1 must be exactly the single-engine path: same event
    // stream, same counters, under every routing policy (with a single
    // live view they all pick replica 0)
    let cfg = CbConfig {
        prefix_cache: true,
        prompt_groups: 4,
        kv_block_tokens: 64,
        seed: 11,
        prompt_vocab: 512,
        ..CbConfig::default()
    };
    let arrivals = poisson_arrivals(&mut Rng::new(42), 8.0, 20.0, 1024);
    assert!(arrivals.len() > 10, "{}", arrivals.len());
    let baseline = engine(cfg.clone()).serve_stream(arrivals.clone(), 20.0);
    assert!(baseline.completed > 0);
    for route in [RouteKind::RoundRobin, RouteKind::LeastLoaded, RouteKind::PrefixAffinity] {
        let mut fleet = ClusterEngine::new(vec![engine(cfg.clone())], route);
        let r = fleet.serve_stream(arrivals.clone(), 20.0).unwrap();
        assert!(r.events.iter().all(|e| e.replica == 0), "{route:?}");
        let events: Vec<CbEvent> = r.events.iter().map(|e| e.event.clone()).collect();
        assert_eq!(events, baseline.events, "{route:?}: event streams diverged");
        assert_eq!(r.replicas[0].completed, baseline.completed, "{route:?}");
        assert_eq!(r.censored(), baseline.censored, "{route:?}");
        assert_eq!(r.replicas[0].kv_rejected, baseline.kv_rejected, "{route:?}");
        assert_eq!(r.replicas[0].prefix_hits, baseline.prefix_hits, "{route:?}");
        assert_eq!(r.replicas[0].prefix_hit_tokens, baseline.prefix_hit_tokens, "{route:?}");
        assert_eq!(r.replicas[0].windows, baseline.windows, "{route:?}");
        assert_eq!(r.routed, vec![arrivals.len() - r.unrouted], "{route:?}");
    }
}

#[test]
fn drain_spills_to_survivors_without_losing_a_request() {
    // remove replica 1 just after the fleet seats its first slots: its
    // in-flight work is evicted recompute-style, its queue spills through
    // the router, and every request still completes exactly once — on a
    // survivor
    let cfg = CbConfig { max_slots: 2, ..CbConfig::default() };
    let arrivals: Vec<Request> =
        (0..30u64).map(|id| Request { id, arrival_s: 0.0, tokens: 1024 }).collect();
    let engines: Vec<CbEngine> = (0..3).map(|_| engine(cfg.clone())).collect();
    let mut fleet = ClusterEngine::new(engines, RouteKind::RoundRobin).with_drain(1, 1e-6);
    let r = fleet.serve_stream(arrivals, 1e4).unwrap();
    assert_eq!(r.drained, Some(1));
    let mut seen = BTreeSet::new();
    for e in &r.events {
        if let CbEvent::Complete { id } = e.event {
            assert!(seen.insert(id), "request {id} completed twice");
            assert_ne!(e.replica, 1, "the drained replica completed request {id}");
        }
    }
    assert_eq!(r.completed(), 30, "a request was lost across the drain");
    assert_eq!(r.replicas[1].completed, 0);
    let victim_evicts = r
        .events
        .iter()
        .filter(|e| e.replica == 1 && matches!(e.event, CbEvent::Evict { .. }))
        .count();
    assert!(victim_evicts > 0, "drain must evict the victim's seated slots");
    assert_eq!(r.kv_violations(), 0);
    // the 10 spilled requests are re-routed, so they count twice
    assert_eq!(r.routed.iter().sum::<usize>(), 30 + 10);
    assert_eq!(r.unrouted, 0);
}

#[test]
fn last_replica_drain_is_skipped_and_reported() {
    // the silent-skip bugfix: draining the only live replica would leave
    // the queue nowhere to spill, so the loop skips it — but it must SAY
    // so. The report carries `drain_skipped`, `drained` stays None, and
    // the replica keeps serving to completion as if no drain were asked.
    let cfg = CbConfig { max_slots: 2, ..CbConfig::default() };
    let arrivals: Vec<Request> =
        (0..12u64).map(|id| Request { id, arrival_s: 0.0, tokens: 1024 }).collect();
    let mut fleet =
        ClusterEngine::new(vec![engine(cfg.clone())], RouteKind::RoundRobin).with_drain(0, 1e-6);
    let r = fleet.serve_stream(arrivals.clone(), 1e4).unwrap();
    assert_eq!(r.drained, None, "a skipped drain must not report as drained");
    assert_eq!(r.drain_skipped, Some(0), "the skip must be surfaced, not silent");
    assert_eq!(r.completed(), 12, "the survivor keeps serving after the skipped drain");
    // and the stream is exactly the undrained run — the skip is a no-op
    let mut plain = ClusterEngine::new(vec![engine(cfg)], RouteKind::RoundRobin);
    let p = plain.serve_stream(arrivals, 1e4).unwrap();
    assert_eq!(r.events, p.events, "skipped drain perturbed the event stream");
}

#[test]
fn prefix_affinity_beats_round_robin_on_grouped_prompts() {
    // the router's acceptance property: on a staggered grouped-prompt
    // trace that both policies fully complete, prefix-affinity must buy a
    // strictly higher fleet hit rate than round-robin. 5 prompt groups
    // over 4 replicas are coprime, so sequential-id round-robin sprays
    // each group across the whole fleet instead of accidentally
    // clustering it
    let cfg = CbConfig {
        prefix_cache: true,
        prompt_groups: 5,
        kv_block_tokens: 64,
        seed: 11,
        prompt_vocab: 512,
        ..CbConfig::default()
    };
    let arrivals: Vec<Request> = (0..64u64)
        .map(|i| Request { id: i, arrival_s: i as f64 * 0.05, tokens: 1024 })
        .collect();
    let run = |route: RouteKind| {
        let engines: Vec<CbEngine> = (0..4).map(|_| engine(cfg.clone())).collect();
        ClusterEngine::new(engines, route).serve_stream(arrivals.clone(), 1e4).unwrap()
    };
    let rr = run(RouteKind::RoundRobin);
    let aff = run(RouteKind::PrefixAffinity);
    assert_eq!(rr.completed(), 64);
    assert_eq!(aff.completed(), 64);
    assert!(rr.fleet_hit_rate() > 0.0, "grouped prompts never shared under round-robin");
    assert!(
        aff.fleet_hit_rate() > rr.fleet_hit_rate(),
        "affinity {} vs round-robin {}",
        aff.fleet_hit_rate(),
        rr.fleet_hit_rate()
    );
    // affinity concentrates without starving anyone of the fleet
    assert!(aff.routed.iter().all(|&c| c > 0), "{:?}", aff.routed);
}

#[test]
fn fleet_rollups_are_exact_sums_of_per_replica_reports() {
    // the windowed-rates regression: fleet bars and throughput aggregate
    // the per-replica reports on the shared virtual clock — the fleet
    // throughput IS the sum of per-replica throughputs (disjoint request
    // sets, one horizon), and the fleet bars ARE the element-wise sum of
    // the aligned per-replica bars
    let cfg = CbConfig::default();
    let arrivals = poisson_arrivals(&mut Rng::new(7), 10.0, 20.0, 1024);
    let engines: Vec<CbEngine> = (0..2).map(|_| engine(cfg.clone())).collect();
    let mut fleet = ClusterEngine::new(engines, RouteKind::RoundRobin);
    let r = fleet.serve_stream(arrivals, 20.0).unwrap();
    assert!(r.completed() > 0);
    assert!(r.replicas.iter().all(|rep| rep.completed > 0), "round-robin fed both replicas");
    let sum: f64 = r.replicas.iter().map(|rep| rep.throughput).sum();
    assert!((r.fleet_throughput() - sum).abs() < 1e-12, "{} vs {sum}", r.fleet_throughput());
    let fleet_windows = r.fleet_windows();
    let len = r.replicas.iter().map(|rep| rep.windows.len()).max().unwrap();
    assert_eq!(fleet_windows.len(), len);
    for (i, &w) in fleet_windows.iter().enumerate() {
        let expect: usize =
            r.replicas.iter().map(|rep| rep.windows.get(i).copied().unwrap_or(0)).sum();
        assert_eq!(w, expect, "window {i}");
    }
    // pooled percentiles come from the union of completion samples
    assert!(r.fleet_p95() > 0.0);
    assert!(r.load_skew() >= 0.0);
}
