//! Chaos soak harness: seeded deterministic fault plans against the
//! multi-replica cluster loop. Every test here enforces the same core
//! contract — a fault schedule reshapes *when* work happens, never
//! *whether* it happens: no request is lost or double-completed across
//! replica kills, checkpoint restores replay no token twice, TTFT is
//! recorded once per request however many times faults requeue it, and
//! the empty plan is bit-identical to no plan at all.

use std::collections::{BTreeMap, BTreeSet};

use astra::comm::trace::BandwidthTrace;
use astra::config::RunConfig;
use astra::coordinator::Cluster;
use astra::model::shape::{TransformerShape, VqSetting};
use astra::parallel::strategies::{Strategy, StrategyKind};
use astra::server::chaos::{assert_chaos_invariants, chaos_invariants};
use astra::server::cluster::{ClusterEngine, ClusterReport, RouteKind};
use astra::server::live::{live_arrivals, live_engine, LiveBackend};
use astra::server::scheduler::{CbConfig, CbEngine, CbEvent};
use astra::server::Request;
use astra::sim::fault::{FaultPlan, ReplicaKill};
use astra::sim::latency::SimParams;
use astra::util::rng::Rng;

fn engine(cfg: CbConfig) -> CbEngine {
    CbEngine::new(
        TransformerShape::paper_encoder(1024),
        Strategy::new(StrategyKind::Astra { vq: VqSetting::new(16, 1024) }, 4),
        SimParams::paper_encoder(),
        BandwidthTrace::constant(100.0, 1e9),
        cfg,
    )
}

fn fleet(cfg: &CbConfig, replicas: usize, plan: Option<FaultPlan>) -> ClusterEngine {
    let engines: Vec<CbEngine> = (0..replicas).map(|_| engine(cfg.clone())).collect();
    let f = ClusterEngine::new(engines, RouteKind::RoundRobin);
    match plan {
        Some(p) => f.with_faults(p),
        None => f,
    }
}

/// Virtual completion time of the last finished request on an
/// all-at-zero arrival trace (latency == completion time there) — the
/// anchor the kill-time fractions below are derived from, so the kills
/// land mid-run whatever the cost model prices the steps at.
fn makespan(report: &ClusterReport) -> f64 {
    report.replicas.iter().map(|r| r.latency.max()).fold(0.0, f64::max)
}

/// Every `Killed` event corresponds to exactly one re-route: restored
/// from a checkpoint or replayed from the prompt.
fn killed_events(report: &ClusterReport) -> usize {
    report.events.iter().filter(|e| matches!(e.event, CbEvent::Killed { .. })).count()
}

#[test]
fn empty_fault_plan_is_bit_identical_on_a_fleet_fixture() {
    // the identity anchor on a fully-loaded fixture (prefix cache +
    // chunked prefill + swap + checkpoints all on): wiring an empty plan
    // must not perturb one bit of the streams or the timing
    let cfg = CbConfig {
        max_slots: 4,
        decode_tokens: 16,
        prefill_chunk_tokens: 256,
        prefix_cache: true,
        kv_block_tokens: 64,
        prompt_groups: 3,
        swap_bandwidth_mbps: 1e5,
        checkpoint_every: 4,
        seed: 11,
        prompt_vocab: 512,
        ..CbConfig::default()
    };
    let arrivals = astra::server::batcher::poisson_arrivals(&mut Rng::new(42), 8.0, 15.0, 1024);
    let p = fleet(&cfg, 3, None).serve_stream(arrivals.clone(), 15.0).unwrap();
    let f = fleet(&cfg, 3, Some(FaultPlan::empty())).serve_stream(arrivals, 15.0).unwrap();
    assert_eq!(f.events, p.events, "empty plan perturbed the decision stream");
    assert!(f.killed.is_empty() && f.restored == 0 && f.replayed == 0);
    for (a, b) in f.replicas.iter().zip(p.replicas.iter()) {
        assert_eq!(a.completed, b.completed);
        assert_eq!(a.windows, b.windows);
        assert_eq!(a.latency.p95().to_bits(), b.latency.p95().to_bits(), "timing drifted");
        assert_eq!(a.swap_bytes, b.swap_bytes);
    }
}

#[test]
fn seeded_soak_holds_the_invariant_checklist_over_100_seeds() {
    // the VOPR loop in miniature: 100 consecutive seeded plans over a
    // 3-replica fleet, full invariant checklist on every run. A failing
    // seed IS the repro — the plan is a pure function of it.
    let horizon = 6.0;
    let base = CbConfig {
        max_slots: 3,
        decode_tokens: 12,
        swap_bandwidth_mbps: 1e5,
        checkpoint_every: 4,
        seed: 7,
        ..CbConfig::default()
    };
    let cap = 5 * engine(base.clone()).kv_projection(1024);
    let cfg = CbConfig { kv_cap_bytes: cap, ..base };
    let (mut kills, mut recovered) = (0usize, 0usize);
    for seed in 0..100u64 {
        let plan = FaultPlan::seeded(seed, 3, horizon);
        let mut rng = Rng::new(7);
        let arrivals =
            astra::server::batcher::poisson_arrivals(&mut rng, 5.0, horizon, 1024);
        let n = arrivals.len();
        let r = fleet(&cfg, 3, Some(plan)).serve_stream(arrivals, horizon).unwrap();
        assert_chaos_invariants(n, &r)
            .unwrap_or_else(|e| panic!("fault seed {seed}: {e:#}"));
        assert_eq!(
            killed_events(&r),
            r.restored + r.replayed,
            "fault seed {seed}: every killed request must be re-routed exactly once"
        );
        kills += r.killed.len();
        recovered += r.restored + r.replayed;
    }
    // the soak must actually exercise the failure paths it guards
    assert!(kills > 0, "100 seeds never killed a replica — the plan generator regressed");
    assert!(recovered > 0, "kills never caught in-flight work — widen the workload");
}

#[test]
fn checkpointed_kills_restore_instead_of_replaying() {
    // a mid-decode kill with checkpoints on: the victim's in-flight slots
    // must come back from the fleet checkpoint store (Restore events, the
    // swap-priced path), not only from prompt replay — and still complete
    // exactly once each
    let cfg = CbConfig {
        max_slots: 2,
        decode_tokens: 64,
        swap_bandwidth_mbps: 1e5,
        checkpoint_every: 4,
        ..CbConfig::default()
    };
    let arrivals: Vec<Request> =
        (0..10u64).map(|id| Request { id, arrival_s: 0.0, tokens: 1024 }).collect();
    let baseline = fleet(&cfg, 2, None).serve_stream(arrivals.clone(), 1e4).unwrap();
    assert_eq!(baseline.completed(), 10);
    let kill_at = 0.5 * makespan(&baseline);
    assert!(kill_at > 0.0);
    let plan = FaultPlan {
        kills: vec![ReplicaKill { replica: 1, at_s: kill_at }],
        ..FaultPlan::default()
    };
    let r = fleet(&cfg, 2, Some(plan)).serve_stream(arrivals, 1e4).unwrap();
    assert_eq!(r.killed, vec![1]);
    assert!(r.restored > 0, "no slot restored from a checkpoint at t={kill_at:.3}");
    let restores =
        r.events.iter().filter(|e| matches!(e.event, CbEvent::Restore { .. })).count();
    assert_eq!(restores, r.restored, "Restore events must match the report");
    assert!(
        r.events.iter().any(|e| matches!(e.event, CbEvent::Checkpoint { .. })),
        "checkpoint_every=4 over 64 decode tokens must emit checkpoints"
    );
    assert_eq!(killed_events(&r), r.restored + r.replayed);
    // nobody lost, nobody double-completed, all on the survivor
    let mut seen = BTreeSet::new();
    for e in &r.events {
        if let CbEvent::Complete { id } = e.event {
            assert!(seen.insert(id), "request {id} completed twice");
        }
    }
    assert_eq!(r.completed(), 10, "a request was lost across the kill");
    assert_chaos_invariants(10, &r).unwrap();
    // restores are NOT swap-ins: the per-replica swap counters only move
    // for genuine preemption traffic, which this cap-less run has none of
    assert!(r.replicas.iter().all(|rep| rep.swap_ins == 0));
}

#[test]
fn kill_requeues_record_ttft_once_and_never_double_count_prefill_chunks() {
    // the Prefilling-eviction audit under fault-induced requeues: kill two
    // replicas mid-run on a chunked-prefill workload, then check (a) TTFT
    // is recorded at most once per request fleet-wide, however many times
    // it was killed and re-admitted, and (b) within every admission
    // episode the PrefillChunk events of a slot tile contiguously — a
    // mid-chunk kill must restart the episode cleanly, never re-cover or
    // skip prompt rows inside one
    let cfg = CbConfig {
        max_slots: 2,
        decode_tokens: 16,
        prefill_chunk_tokens: 256,
        ..CbConfig::default()
    };
    let arrivals: Vec<Request> =
        (0..12u64).map(|id| Request { id, arrival_s: 0.0, tokens: 1024 }).collect();
    let baseline = fleet(&cfg, 3, None).serve_stream(arrivals.clone(), 1e4).unwrap();
    let m = makespan(&baseline);
    let plan = FaultPlan {
        kills: vec![
            ReplicaKill { replica: 1, at_s: 0.35 * m },
            ReplicaKill { replica: 2, at_s: 0.55 * m },
        ],
        ..FaultPlan::default()
    };
    let r = fleet(&cfg, 3, Some(plan)).serve_stream(arrivals, 1e4).unwrap();
    assert_eq!(r.killed, vec![1, 2]);
    assert!(killed_events(&r) > 0, "the kills caught no work at all");
    assert_chaos_invariants(12, &r).unwrap();

    // (a) TTFT once per request across every replica it ever visited
    let admitted: BTreeSet<u64> = r
        .events
        .iter()
        .flat_map(|e| match &e.event {
            CbEvent::Admit { ids } => ids.clone(),
            _ => Vec::new(),
        })
        .collect();
    let ttft_samples: usize = r.replicas.iter().map(|rep| rep.ttft.len()).sum();
    assert!(
        ttft_samples <= admitted.len(),
        "{ttft_samples} TTFT samples over {} distinct admitted requests — \
         a fault requeue re-recorded a first token",
        admitted.len()
    );

    // (b) chunk coverage per admission episode: contiguous, no overlap.
    // An episode opens at Admit and closes at Complete/Evict/Killed;
    // within it each chunk must start where the previous one ended.
    let mut cursor: BTreeMap<(usize, u64), Option<usize>> = BTreeMap::new();
    for e in &r.events {
        match &e.event {
            CbEvent::Admit { ids } => {
                for &id in ids {
                    cursor.insert((e.replica, id), None);
                }
            }
            CbEvent::PrefillChunk { id, lo, hi } => {
                assert!(hi > lo && *hi <= 1024, "degenerate chunk [{lo},{hi})");
                let c = cursor
                    .get_mut(&(e.replica, *id))
                    .unwrap_or_else(|| panic!("chunk for {id} outside any episode"));
                if let Some(prev_hi) = *c {
                    assert_eq!(
                        *lo, prev_hi,
                        "request {id} on replica {}: chunk [{lo},{hi}) double-counts or \
                         skips rows (episode cursor at {prev_hi})",
                        e.replica
                    );
                }
                *c = Some(*hi);
            }
            CbEvent::Complete { id } | CbEvent::Evict { id } | CbEvent::Killed { id } => {
                cursor.remove(&(e.replica, *id));
            }
            _ => {}
        }
    }
}

#[test]
fn live_fleet_under_faults_matches_the_model_and_recovers() {
    // the differential harness extended to fault schedules: a live fleet
    // (real DecodeSessions, real checkpoint-restore replay) and the cost
    // model must emit identical replica-tagged streams INCLUDING the
    // Killed/Checkpoint/Restore events, and the kill must lose nobody
    let shape = TransformerShape {
        n_layers: 2,
        d_model: 16,
        n_heads: 2,
        d_ff: 32,
        seq_len: 16,
        elem_bytes: 4,
    };
    let config = RunConfig { n_devices: 2, ..RunConfig::default() };
    let cluster =
        Cluster::synthetic_decoder(&shape, 32, VqSetting::new(2, 8), config, 25).unwrap();
    let seq = cluster.artifact.meta.seq_len;
    let cfg = CbConfig {
        max_slots: 4,
        max_batch: 4,
        decode_tokens: 6,
        prefix_cache: true,
        kv_block_tokens: 4,
        prompt_groups: 2,
        swap_bandwidth_mbps: 1e5,
        checkpoint_every: 2,
        ..CbConfig::default()
    };
    let params = SimParams::paper_encoder();
    let trace = BandwidthTrace::constant(100.0, 1e9);
    let arrivals = live_arrivals(&mut Rng::new(301), 25.0, 4.0, seq);
    assert!(arrivals.len() > 3, "{}", arrivals.len());
    let n = arrivals.len();
    // replica 0 dies at t=2.0, mid-trace for this workload (the drain
    // differential pins the same instant)
    let plan = FaultPlan {
        kills: vec![ReplicaKill { replica: 0, at_s: 2.0 }],
        ..FaultPlan::default()
    };
    let pinned = live_engine(&cluster, cfg.clone(), params.clone(), trace.clone()).cfg;
    let mk_fleet = || {
        let engines: Vec<_> = (0..2)
            .map(|_| live_engine(&cluster, cfg.clone(), params.clone(), trace.clone()))
            .collect();
        ClusterEngine::new(engines, RouteKind::RoundRobin).with_faults(plan.clone())
    };
    let m = mk_fleet().serve_stream(arrivals.clone(), 1e4).unwrap();
    let mut backends: Vec<LiveBackend> =
        (0..2).map(|_| LiveBackend::for_config(&cluster, &pinned)).collect();
    let l = mk_fleet().serve_stream_with(&mut backends, arrivals, 1e4).unwrap();

    assert_eq!(m.events, l.events, "fleet streams diverged under the fault plan");
    assert_eq!(m.killed, vec![0]);
    assert_eq!(l.killed, vec![0]);
    assert_eq!(m.restored, l.restored);
    assert_eq!(m.replayed, l.replayed);
    assert!(killed_events(&m) > 0, "the kill at t=2.0 caught no work");
    assert_eq!(m.completed(), n, "a request was lost across the kill");
    for (name, ok, detail) in chaos_invariants(n, &l) {
        assert!(ok, "live run broke `{name}`: {detail}");
    }
    // the survivor's real session memory kept agreeing with the model
    assert!(l.replicas.iter().all(|rep| rep.kv_violations == 0));
    // every survivor-side completion produced a real full generation
    let done: BTreeSet<u64> = m
        .events
        .iter()
        .filter_map(|e| match e.event {
            CbEvent::Complete { id } => Some(id),
            _ => None,
        })
        .collect();
    let full = backends
        .iter()
        .flat_map(|b| b.generations.iter())
        .filter(|(id, toks)| done.contains(id) && !toks.is_empty())
        .count();
    assert!(full > 0, "no completed request carries a real generation");
}

/// Every id an event mentions, for the cancellation-terminality sweep.
fn event_ids(e: &CbEvent) -> Vec<u64> {
    match e {
        CbEvent::Admit { ids } | CbEvent::Decode { ids } => ids.clone(),
        CbEvent::Complete { id }
        | CbEvent::Evict { id }
        | CbEvent::Reject { id }
        | CbEvent::PrefillChunk { id, .. }
        | CbEvent::PrefixHit { id, .. }
        | CbEvent::SwapOut { id }
        | CbEvent::SwapIn { id }
        | CbEvent::Killed { id }
        | CbEvent::Checkpoint { id }
        | CbEvent::Restore { id }
        | CbEvent::Cancelled { id } => vec![*id],
        // a plan swap names candidate indices, not requests
        CbEvent::Replan { .. } => Vec::new(),
    }
}

#[test]
fn cancel_heavy_soak_under_faults_keeps_the_checklist() {
    // cancellation x chaos: an overloaded fleet with impatient clients
    // (heavy-tailed decode lengths, swap parking, periodic checkpoints)
    // soaked over seeded fault plans. The seed sweep interleaves cancels
    // with every other lifecycle edge — cancel of a swapped-out request,
    // cancel between a checkpoint and its restore, cancel of a request a
    // replica kill just orphaned onto a survivor's queue — and on every
    // run the extended accounting must close (completed + rejected +
    // censored + cancelled == arrivals), no request may be
    // double-cancelled, cancellation must be terminal, and the KV pool
    // must stay violation-free.
    let horizon = 6.0;
    let base = CbConfig {
        max_slots: 3,
        decode_tokens: 12,
        swap_bandwidth_mbps: 1e5,
        checkpoint_every: 4,
        patience_s: 0.8,
        patience_spread: 1.0,
        length_tail_alpha: 1.2,
        seed: 7,
        ..CbConfig::default()
    };
    let cap = 5 * engine(base.clone()).kv_projection(1024);
    let cfg = CbConfig { kv_cap_bytes: cap, ..base };
    let (mut kills, mut cancels, mut completes) = (0usize, 0usize, 0usize);
    for seed in 0..60u64 {
        let plan = FaultPlan::seeded(seed, 3, horizon);
        let arrivals =
            astra::server::batcher::poisson_arrivals(&mut Rng::new(7), 12.0, horizon, 1024);
        let n = arrivals.len();
        let r = fleet(&cfg, 3, Some(plan)).serve_stream(arrivals, horizon).unwrap();
        assert_chaos_invariants(n, &r)
            .unwrap_or_else(|e| panic!("fault seed {seed}: {e:#}"));
        // cancellation is terminal fleet-wide: once an id is cancelled,
        // no later event of any kind may mention it
        let mut gone: BTreeSet<u64> = BTreeSet::new();
        for e in &r.events {
            for id in event_ids(&e.event) {
                assert!(
                    !gone.contains(&id),
                    "fault seed {seed}: {:?} on replica {} touches cancelled request {id}",
                    e.event,
                    e.replica
                );
            }
            if let CbEvent::Cancelled { id } = e.event {
                gone.insert(id);
            }
        }
        kills += r.killed.len();
        cancels += r.cancelled();
        completes += r.completed();
    }
    // the soak must actually exercise what it guards
    assert!(kills > 0, "60 seeds never killed a replica");
    assert!(cancels > 0, "impatient clients never cancelled — patience too generous");
    assert!(completes > 0, "nothing completed — patience too harsh");
}
