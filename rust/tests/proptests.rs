//! Property-based tests (randomized with the in-crate PRNG; the vendored
//! image has no proptest crate) over coordinator/VQ/comm invariants.
//! Each property runs across many random cases with distinct seeds.

use std::collections::BTreeMap;

use astra::comm::collective::{allgather, allreduce};
use astra::comm::message::Message;
use astra::comm::trace::BandwidthTrace;
use astra::coordinator::TokenPartition;
use astra::model::shape::{ceil_log2, TransformerShape, VqSetting};
use astra::parallel::strategies::{Strategy, StrategyKind};
use astra::server::cluster::{ClusterEngine, RouteKind};
use astra::server::policy::PolicyKind;
use astra::server::scheduler::{CbConfig, CbEngine, CbEvent};
use astra::server::Request;
use astra::sim::fault::FaultPlan;
use astra::sim::latency::{
    evaluate, evaluate_batched, evaluate_on_trace, evaluate_on_trace_batched, SimParams,
};
use astra::tensor::Tensor;
use astra::util::rng::Rng;
use astra::vq::{pack_indices, unpack_indices, Codebook};

const CASES: usize = 60;

#[test]
fn prop_pack_unpack_roundtrip() {
    let mut rng = Rng::new(100);
    for case in 0..CASES {
        let bits = 1 + rng.below(20);
        let count = 1 + rng.below(500);
        let limit: u64 = 1u64 << bits;
        let idx: Vec<u32> = (0..count).map(|_| (rng.next_u64() % limit) as u32).collect();
        let packed = pack_indices(&idx, bits).unwrap();
        let back = unpack_indices(&packed, count, bits).unwrap();
        assert_eq!(back, idx, "case {case}: bits={bits} count={count}");
        // packed length is exactly ceil(count*bits/8)
        assert_eq!(packed.len(), (count * bits + 7) / 8);
    }
}

#[test]
fn prop_vq_roundtrip_is_projection() {
    // decode(encode(x)) is idempotent; every returned index is valid;
    // nearest-neighbour assignment never loses to a random assignment.
    let mut rng = Rng::new(200);
    for case in 0..20 {
        let g = 1 + rng.below(4);
        let k = 2 + rng.below(30);
        let dg = 1 + rng.below(8);
        let t = 1 + rng.below(40);
        let mut data = vec![0.0f32; g * k * dg];
        rng.fill_normal(&mut data);
        let cb = Codebook::new(g, k, dg, data).unwrap();
        let mut x = Tensor::zeros(&[t, g * dg]);
        rng.fill_normal(&mut x.data);
        let idx = cb.encode(&x).unwrap();
        assert!(idx.iter().all(|&i| (i as usize) < k), "case {case}");
        let x1 = cb.decode(&idx, t).unwrap();
        let x2 = cb.roundtrip(&x1).unwrap();
        assert_eq!(x1.data, x2.data, "case {case}: projection not idempotent");
        let d_opt = cb.distortion(&x).unwrap();
        let rand_idx: Vec<u32> = (0..t * g).map(|_| rng.below(k) as u32).collect();
        let x_rand = cb.decode(&rand_idx, t).unwrap();
        let d_rand = x
            .data
            .iter()
            .zip(x_rand.data.iter())
            .map(|(a, b)| (a - b) * (a - b))
            .sum::<f32>()
            / x.numel() as f32;
        assert!(d_opt <= d_rand + 1e-5, "case {case}: {d_opt} > {d_rand}");
    }
}

#[test]
fn prop_partition_invariants() {
    let mut rng = Rng::new(300);
    for _ in 0..CASES {
        let n = 1 + rng.below(8);
        let t = n * (1 + rng.below(64));
        let even = TokenPartition::even(t, n).unwrap();
        assert_eq!(even.total(), t);
        assert!((even.fpar() - 1.0 / n as f64).abs() < 1e-9);
        let p = TokenPartition::random(&mut rng, t, n);
        assert_eq!(p.total(), t);
        assert!(p.fpar() >= 1.0 / n as f64 - 1e-9);
        assert!(p.fpar() <= 1.0 + 1e-9);
        let mut acc = 0;
        for d in 0..n {
            assert_eq!(p.start(d), acc);
            acc += p.sizes[d];
        }
        // Eq. 36 identity between count variance and FPAR
        let k = n as f64;
        let want = (t * t) as f64 / k * (p.fpar() - 1.0 / k);
        assert!((p.size_variance() - want).abs() < 1e-6 * (t * t) as f64);
    }
}

#[test]
fn prop_proportional_partition_matches_speeds() {
    let mut rng = Rng::new(400);
    for _ in 0..CASES {
        let n = 2 + rng.below(6);
        let t = 64 + rng.below(512);
        let speeds: Vec<f64> = (0..n).map(|_| 0.25 + rng.f64() * 4.0).collect();
        let p = TokenPartition::proportional(t, &speeds).unwrap();
        assert_eq!(p.total(), t);
        let fastest = speeds
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0;
        let slowest = speeds
            .iter()
            .enumerate()
            .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0;
        assert!(p.sizes[fastest] >= p.sizes[slowest], "{speeds:?} -> {:?}", p.sizes);
    }
}

#[test]
fn prop_message_accounting() {
    let mut rng = Rng::new(500);
    for _ in 0..CASES {
        let tokens = 1 + rng.below(200);
        let groups = 1 + rng.below(32);
        let k = 2 + rng.below(2000);
        let bits = ceil_log2(k);
        let idx: Vec<u32> = (0..tokens * groups).map(|_| rng.below(k) as u32).collect();
        let m = Message::vq(0, 0, &idx, tokens, groups, bits).unwrap();
        assert_eq!(m.payload_bits(), tokens * groups * bits);
        assert_eq!(m.bits_per_token(), (groups * bits) as f64);
        assert_eq!(m.wire_bytes(), 16 + (tokens * groups * bits + 7) / 8);
        assert_eq!(m.vq_indices().unwrap(), idx);
    }
}

#[test]
fn prop_collective_costs_scale() {
    let mut rng = Rng::new(600);
    for _ in 0..CASES {
        let bits = rng.f64() * 1e9;
        let n = 2 + rng.below(15);
        let ag = allgather(bits, n);
        let ar = allreduce(bits, n);
        assert!((ar.bits - 2.0 * ag.bits).abs() < 1e-3);
        assert_eq!(ar.stages, 2 * ag.stages);
        assert!(ag.bits < bits);
        assert!(ag.bits >= bits * 0.5 - 1e-3);
    }
}

#[test]
fn prop_batch1_equals_unbatched_evaluation() {
    // the continuous-batching engine prices work through the batched
    // evaluators; at batch size 1 they must agree EXACTLY with the
    // unbatched `evaluate`/`evaluate_on_trace` on the same trace — over
    // random cluster sizes, strategies, bandwidths, start times, and both
    // constant and Markovian link configs. The live-vs-model differential
    // harness leans on this identity.
    let mut rng = Rng::new(1000);
    for case in 0..CASES {
        let n = 2 + rng.below(7);
        let t = n * (8 + rng.below(128));
        let shape = TransformerShape::paper_encoder(t);
        let protos = astra::parallel::strategies::figure1_strategies(4);
        let s = Strategy::new(protos[rng.below(protos.len())].kind, n);
        let params = SimParams::paper_encoder();
        let bw = 5.0 + rng.f64() * 495.0;
        let states = 2 + rng.below(8);
        let trace = if rng.chance(0.5) {
            BandwidthTrace::constant(bw, 1e9)
        } else {
            BandwidthTrace::markovian(&mut rng, 0.2 * bw, bw, states, 1.0, 500.0)
        };
        let t0 = rng.f64() * 100.0;
        let label = format!("case {case}: {} n={n} t={t} bw={bw:.1} t0={t0:.2}", s.name());
        let prefill = s.schedule(&shape);
        let a = evaluate_on_trace(&prefill, &params, &trace, t0);
        let b = evaluate_on_trace_batched(&prefill, &params, &trace, t0, 1);
        assert_eq!(a.compute_s, b.compute_s, "{label}");
        assert_eq!(a.comm_s, b.comm_s, "{label}");
        // static evaluator too
        let sa = evaluate(&prefill, &params, bw);
        let sb = evaluate_batched(&prefill, &params, bw, 1);
        assert_eq!(sa.compute_s, sb.compute_s, "{label}");
        assert_eq!(sa.comm_s, sb.comm_s, "{label}");
        // and the decode-step schedule the scheduler interleaves
        let step = s.decode_step_schedule(&shape, t + rng.below(64));
        let da = evaluate_on_trace(&step, &params, &trace, t0);
        let db = evaluate_on_trace_batched(&step, &params, &trace, t0, 1);
        assert_eq!(da.compute_s, db.compute_s, "{label}");
        assert_eq!(da.comm_s, db.comm_s, "{label}");
    }
}

#[test]
fn prop_chunked_prefill_covers_prompts_and_anchors_to_unchunked() {
    // over random traces and configs:
    //  (1) per admission episode, a request's chunk events tile
    //      [0, prompt_len) contiguously and in order, each within the
    //      per-iteration budget, and nothing decodes or completes before
    //      its prompt is fully prefilled;
    //  (2) a chunk budget >= the longest prompt reproduces the unchunked
    //      scheduler's event stream exactly.
    let mut rng = Rng::new(1100);
    for case in 0..25 {
        let n = 2 + rng.below(4);
        let t = n * (8 + rng.below(64));
        let shape = TransformerShape::paper_encoder(t);
        let strategy = Strategy::new(StrategyKind::Astra { vq: VqSetting::new(16, 1024) }, n);
        let chunk = 1 + rng.below(t);
        let cfg = CbConfig {
            max_slots: 2 + rng.below(6),
            max_batch: 1 + rng.below(4),
            max_wait_s: 0.0,
            decode_tokens: 1 + rng.below(12),
            prefill_chunk_tokens: chunk,
            ..CbConfig::default()
        };
        let mut arrivals = Vec::new();
        let mut at = 0.0;
        let mut tokens: BTreeMap<u64, usize> = BTreeMap::new();
        for id in 1..=(4 + rng.below(20)) as u64 {
            at += rng.exp(5.0 + rng.f64() * 20.0);
            let toks = 1 + rng.below(t);
            tokens.insert(id, toks);
            arrivals.push(Request { id, arrival_s: at, tokens: toks });
        }
        let mk = |cfg: CbConfig| {
            CbEngine::new(
                shape,
                strategy,
                SimParams::paper_encoder(),
                BandwidthTrace::constant(100.0, 1e9),
                cfg,
            )
        };
        let r = mk(cfg.clone()).serve_stream(arrivals.clone(), 1e5);
        let label = format!("case {case}: chunk={chunk} t={t}");
        // walk the event stream tracking chunk progress per slot episode
        let mut progress: BTreeMap<u64, usize> = BTreeMap::new();
        let prefilled = |progress: &BTreeMap<u64, usize>, id: &u64| {
            tokens[id] <= chunk || progress.get(id) == Some(&tokens[id])
        };
        for e in &r.events {
            match e {
                CbEvent::Admit { ids } => {
                    for id in ids {
                        progress.insert(*id, 0);
                    }
                }
                CbEvent::PrefillChunk { id, lo, hi } => {
                    assert!(tokens[id] > chunk, "{label}: short prompt emitted a chunk");
                    assert_eq!(progress[id], *lo, "{label}: request {id} chunk out of order");
                    assert!(hi > lo && *hi <= tokens[id], "{label}: bad range [{lo},{hi})");
                    assert!(hi - lo <= chunk, "{label}: chunk over budget");
                    progress.insert(*id, *hi);
                }
                CbEvent::Decode { ids } => {
                    for id in ids {
                        assert!(prefilled(&progress, id), "{label}: {id} decoded mid-prefill");
                    }
                }
                CbEvent::Complete { id } => {
                    assert!(prefilled(&progress, id), "{label}: {id} completed mid-prefill");
                }
                CbEvent::Evict { id } => {
                    progress.remove(id); // recompute: next episode restarts
                }
                CbEvent::Reject { .. } => {}
                // prefix cache, swap, faults, cancellation, and
                // re-planning are all off in this property run
                CbEvent::PrefixHit { .. }
                | CbEvent::SwapOut { .. }
                | CbEvent::SwapIn { .. }
                | CbEvent::Killed { .. }
                | CbEvent::Checkpoint { .. }
                | CbEvent::Restore { .. }
                | CbEvent::Cancelled { .. }
                | CbEvent::Replan { .. } => {
                    unreachable!("{label}: feature event without the feature enabled")
                }
            }
        }
        // (2) the regression anchor on the same trace
        let big = t + rng.below(100);
        let anchored = mk(CbConfig { prefill_chunk_tokens: big, ..cfg.clone() })
            .serve_stream(arrivals.clone(), 1e5);
        let plain = mk(CbConfig { prefill_chunk_tokens: 0, ..cfg })
            .serve_stream(arrivals, 1e5);
        assert_eq!(anchored.events, plain.events, "{label}: anchor diverged at budget {big}");
        assert_eq!(anchored.prefill_chunks, 0, "{label}");
    }
}

#[test]
fn prop_kv_pool_attach_detach_never_leaks_blocks() {
    // random admission/eviction sequences over the pool + radix tree:
    // refcounts return to zero, resident bytes always equal an
    // independent recomputation, and draining every slot leaves only
    // reclaimable cached blocks which reclaim to exactly zero
    use astra::kv::{KvPool, RadixTree};

    let mut rng = Rng::new(4200);
    for case in 0..30 {
        let block = 1 + rng.below(6);
        let tree_b = block;
        let mut pool = KvPool::new(0);
        let mut tree = RadixTree::new(tree_b);
        // (attached blocks, private bytes) per live slot
        let mut live: Vec<(u64, Vec<u64>, usize)> = Vec::new();
        let mut next_slot = 0u64;
        let mut expected_private = 0usize;
        for _step in 0..120 {
            if live.is_empty() || rng.chance(0.55) {
                // admit: a prompt from a small pool of streams so prefixes
                // really collide
                let group = rng.below(3) as u64;
                let tokens = 1 + rng.below(24);
                let prompt: Vec<usize> =
                    (0..tokens).map(|i| (group as usize * 1000 + i) % 97).collect();
                let (hit, extendable) = tree.lookup(&prompt, &|b| pool.block_ready(b));
                for &b in &hit {
                    pool.ref_block(b);
                }
                let mut blocks = hit.clone();
                if extendable {
                    let created = tree.extend(&prompt, hit.len(), &mut |lo, hi| {
                        pool.create_block(lo, hi, (hi - lo) * 8)
                    });
                    // creator rows exist immediately in this model run:
                    // bytes pass through private before marking ready
                    for &b in &created {
                        pool.acquire_private(tree_b * 8);
                        pool.mark_ready(b);
                        blocks.push(b);
                    }
                }
                let covered = blocks.len() * tree_b;
                let private = (tokens - covered.min(tokens)) * 8 + rng.below(64);
                pool.acquire_private(private);
                expected_private += private;
                live.push((next_slot, blocks, private));
                next_slot += 1;
            } else {
                // retire a random slot: release private, unref blocks
                let i = rng.below(live.len());
                let (_, blocks, private) = live.swap_remove(i);
                pool.release_private(private);
                expected_private -= private;
                for b in blocks {
                    pool.unref_block(b);
                }
            }
            assert_eq!(
                pool.private_bytes(),
                expected_private,
                "case {case}: private bytes drifted"
            );
            assert!(
                pool.resident_bytes() >= pool.private_bytes(),
                "case {case}: resident below private"
            );
        }
        // drain everything: all refcounts must return to zero
        for (_, blocks, private) in live.drain(..) {
            pool.release_private(private);
            for b in blocks {
                pool.unref_block(b);
            }
        }
        assert!(pool.quiescent(), "case {case}: refcounts leaked");
        assert_eq!(pool.private_bytes(), 0, "case {case}");
        // every remaining byte is cached and reclaimable down to zero
        while let Some(victim) = pool.lru_cached() {
            for b in tree.remove_subtree(victim) {
                pool.drop_cached(b);
            }
        }
        assert_eq!(pool.resident_bytes(), 0, "case {case}: cached bytes leaked");
        assert_eq!(pool.block_count(), 0, "case {case}: block records leaked");
        assert_eq!(tree.block_count(), 0, "case {case}: tree entries leaked");
    }
}

#[test]
fn prop_pool_accounting_equals_appendix_g_when_sharing_is_off() {
    // with sharing disabled the engine's per-slot accounting must equal
    // kv_cache_bytes_astra_live EXACTLY (the pool is then the old flat
    // KvBudget arithmetic), and the positional variant must agree at
    // full-window prompts — the identity that keeps flag-off streams
    // bit-identical
    use astra::model::{kv_cache_bytes_astra_live, kv_cache_bytes_astra_positional};

    let mut rng = Rng::new(4300);
    for _ in 0..CASES {
        let n = 2 + rng.below(6);
        let t = n * (4 + rng.below(64));
        let shape = TransformerShape::paper_encoder(t);
        let vq = VqSetting::new(16, 1024);
        let engine = CbEngine::new(
            shape,
            Strategy::new(StrategyKind::Astra { vq }, n),
            SimParams::paper_encoder(),
            BandwidthTrace::constant(100.0, 1e9),
            CbConfig::default(),
        );
        let prompt = 1 + rng.below(t);
        let generated = rng.below(64);
        assert_eq!(
            engine.kv_slot_bytes(prompt, generated),
            kv_cache_bytes_astra_live(&shape, prompt, generated, 4, n, 16, 1024)
        );
        assert_eq!(
            engine.kv_slot_bytes_positional(t, generated),
            kv_cache_bytes_astra_positional(&shape, t, generated, 4, n, 16, 1024)
        );
        assert_eq!(
            engine.kv_slot_bytes_positional(t, generated),
            engine.kv_slot_bytes(t, generated),
            "positional accounting must equal classic at the full window (t={t}, n={n})"
        );
        // block bytes telescope: summing random block edges reproduces the
        // positional total exactly
        let b = 1 + rng.below(16);
        let mut sum = 0usize;
        let mut lo = 0usize;
        while lo < t {
            let hi = (lo + b).min(t);
            sum += kv_cache_bytes_astra_positional(&shape, hi, 0, 4, n, 16, 1024)
                - kv_cache_bytes_astra_positional(&shape, lo, 0, 4, n, 16, 1024);
            lo = hi;
        }
        assert_eq!(sum, kv_cache_bytes_astra_positional(&shape, t, 0, 4, n, 16, 1024));
    }
}

#[test]
fn prop_prefix_cache_off_paths_reproduce_baseline_streams() {
    // the PR-3 stream anchors, over random traces: (a) prefix cache ON
    // with a block size above every prompt shares nothing and must
    // reproduce the OFF stream bit for bit (full-length prompts, so the
    // positional accounting coincides too); (b) a swap bandwidth too low
    // to ever win must reproduce the swap-off stream; (c) zero jitter is
    // the identity on decode budgets
    let mut rng = Rng::new(4400);
    for case in 0..12 {
        let n = 2 + rng.below(4);
        let t = n * (8 + rng.below(48));
        let shape = TransformerShape::paper_encoder(t);
        let strategy = Strategy::new(StrategyKind::Astra { vq: VqSetting::new(16, 1024) }, n);
        let cap_slots = rng.below(3); // 0 = uncapped
        // chunked prefill only rides the uncapped cases here: a capped
        // run with a mid-replay slot prices bytes through the positional
        // accounting when the prefix cache is on, which only coincides
        // with the classic bytes at block-replay boundaries of {0, t} —
        // the oversized-block anchor therefore pins (cap, no chunks) and
        // (chunks, no cap); the live-vs-model harness covers chunk+cap
        // with the prefix cache for both backends at once
        let base = CbConfig {
            max_slots: 2 + rng.below(4),
            max_batch: 1 + rng.below(4),
            decode_tokens: 1 + rng.below(24),
            prefill_chunk_tokens: if cap_slots == 0 && rng.chance(0.7) {
                1 + rng.below(t)
            } else {
                0
            },
            ..CbConfig::default()
        };
        let mk = |cfg: CbConfig| {
            CbEngine::new(
                shape,
                strategy,
                SimParams::paper_encoder(),
                BandwidthTrace::constant(100.0, 1e9),
                cfg,
            )
        };
        let cap = cap_slots * mk(base.clone()).kv_projection(t);
        let off = CbConfig { kv_cap_bytes: cap, ..base.clone() };
        let arrivals = {
            let mut arr = Vec::new();
            let mut at = 0.0;
            for id in 1..=(6 + rng.below(20)) as u64 {
                at += rng.exp(10.0);
                arr.push(Request { id, arrival_s: at, tokens: t });
            }
            arr
        };
        let label = format!("case {case}: t={t} cap={cap}");
        let r_off = mk(off.clone()).serve_stream(arrivals.clone(), 1e5);
        let r_prefix = mk(CbConfig {
            prefix_cache: true,
            kv_block_tokens: t + 1 + rng.below(64),
            prompt_groups: 1 + rng.below(3),
            seed: rng.next_u64(),
            ..off.clone()
        })
        .serve_stream(arrivals.clone(), 1e5);
        assert_eq!(r_off.events, r_prefix.events, "{label}: oversized-block anchor");
        assert_eq!(r_prefix.prefix_hits, 0, "{label}");
        let r_slow_swap = mk(CbConfig { swap_bandwidth_mbps: 1e-9, ..off.clone() })
            .serve_stream(arrivals.clone(), 1e5);
        assert_eq!(r_off.events, r_slow_swap.events, "{label}: slow-swap anchor");
        assert_eq!(r_slow_swap.swap_outs, 0, "{label}");
        let e = mk(CbConfig { decode_jitter: 0, seed: rng.next_u64(), ..off });
        for id in 0..20u64 {
            assert_eq!(e.decode_budget(id), base.decode_tokens, "{label}: jitter-0 identity");
        }
    }
}

#[test]
fn prop_fifo_policy_layer_reproduces_baseline_streams() {
    // the policy-refactor anchors, over random traces and configs
    // (chunked or not, KV-capped or not):
    //  (a) configuring classes under the default FIFO policy is pure
    //      accounting — the event stream is bit-identical to the
    //      classless run;
    //  (b) the prefix-aware policy with the prefix cache off and no cap
    //      degenerates to FIFO exactly (all coverage zero, aging
    //      monotone in queue order, nothing to skip);
    //  (c) the slo-class policy with no classes configured and no cap
    //      likewise reproduces the FIFO stream (single implicit class).
    let mut rng = Rng::new(4500);
    for case in 0..12 {
        let n = 2 + rng.below(4);
        let t = n * (8 + rng.below(48));
        let shape = TransformerShape::paper_encoder(t);
        let strategy = Strategy::new(StrategyKind::Astra { vq: VqSetting::new(16, 1024) }, n);
        let cap_slots = rng.below(3); // 0 = uncapped
        let base = CbConfig {
            max_slots: 2 + rng.below(4),
            max_batch: 1 + rng.below(4),
            decode_tokens: 1 + rng.below(24),
            prefill_chunk_tokens: if rng.chance(0.5) { 1 + rng.below(t) } else { 0 },
            ..CbConfig::default()
        };
        let mk = |cfg: CbConfig| {
            CbEngine::new(
                shape,
                strategy,
                SimParams::paper_encoder(),
                BandwidthTrace::constant(100.0, 1e9),
                cfg,
            )
        };
        let cap = cap_slots * mk(base.clone()).kv_projection(t);
        let plain = CbConfig { kv_cap_bytes: cap, ..base.clone() };
        let arrivals = {
            let mut arr = Vec::new();
            let mut at = 0.0;
            for id in 1..=(6 + rng.below(20)) as u64 {
                at += rng.exp(10.0);
                arr.push(Request { id, arrival_s: at, tokens: t });
            }
            arr
        };
        let label = format!("case {case}: t={t} cap={cap}");
        let r_plain = mk(plain.clone()).serve_stream(arrivals.clone(), 1e5);
        // (a) classes are reporting-only under FIFO
        let r_classed = mk(CbConfig {
            classes: vec![2.0 + rng.f64(), 0.1 + rng.f64(), 8.0],
            ..plain.clone()
        })
        .serve_stream(arrivals.clone(), 1e5);
        assert_eq!(r_plain.events, r_classed.events, "{label}: classes-under-fifo anchor");
        assert_eq!(r_classed.classes.len(), 3, "{label}");
        assert_eq!(
            r_classed.classes.iter().map(|c| c.completed).sum::<usize>(),
            r_classed.completed,
            "{label}"
        );
        // (b) + (c): reordering policies with nothing to reorder on
        // (and no cap, so nothing is ever skipped) degenerate to FIFO
        if cap == 0 {
            let r_aware = mk(CbConfig { policy: PolicyKind::PrefixAware, ..plain.clone() })
                .serve_stream(arrivals.clone(), 1e5);
            assert_eq!(r_plain.events, r_aware.events, "{label}: prefix-aware-off anchor");
            let r_slo = mk(CbConfig { policy: PolicyKind::SloClass, ..plain })
                .serve_stream(arrivals, 1e5);
            assert_eq!(r_plain.events, r_slo.events, "{label}: classless slo-class anchor");
            assert_eq!(r_slo.slo_preemptions, 0, "{label}");
        }
    }
}

#[test]
fn prop_single_replica_cluster_reproduces_engine_streams() {
    // the fleet-refactor anchor, over random traces and configs: a
    // 1-replica ClusterEngine is the single-engine path exactly — the
    // same event stream (all tagged replica 0), the same counters —
    // under every routing policy, with chunked prefill, KV caps, the
    // prefix cache, and truncating horizons all in play
    let mut rng = Rng::new(4700);
    for case in 0..12 {
        let n = 2 + rng.below(4);
        let t = n * (8 + rng.below(48));
        let shape = TransformerShape::paper_encoder(t);
        let strategy = Strategy::new(StrategyKind::Astra { vq: VqSetting::new(16, 1024) }, n);
        let cap_slots = rng.below(3); // 0 = uncapped
        let base = CbConfig {
            max_slots: 2 + rng.below(4),
            max_batch: 1 + rng.below(4),
            decode_tokens: 1 + rng.below(24),
            prefill_chunk_tokens: if rng.chance(0.5) { 1 + rng.below(t) } else { 0 },
            prefix_cache: rng.chance(0.5),
            kv_block_tokens: 1 + rng.below(t),
            prompt_groups: rng.below(4),
            seed: rng.next_u64(),
            ..CbConfig::default()
        };
        let mk = |cfg: CbConfig| {
            CbEngine::new(
                shape,
                strategy,
                SimParams::paper_encoder(),
                BandwidthTrace::constant(100.0, 1e9),
                cfg,
            )
        };
        let cap = cap_slots * mk(base.clone()).kv_projection(t);
        let cfg = CbConfig { kv_cap_bytes: cap, ..base };
        let arrivals = {
            let mut arr = Vec::new();
            let mut at = 0.0;
            for id in 1..=(6 + rng.below(20)) as u64 {
                at += rng.exp(10.0);
                arr.push(Request { id, arrival_s: at, tokens: t });
            }
            arr
        };
        // a short horizon exercises the censoring paths too
        let horizon = 1.0 + rng.f64() * 20.0;
        let r = mk(cfg.clone()).serve_stream(arrivals.clone(), horizon);
        let label = format!("case {case}: t={t} cap={cap} horizon={horizon:.2}");
        for route in [RouteKind::RoundRobin, RouteKind::LeastLoaded, RouteKind::PrefixAffinity] {
            let mut fleet = ClusterEngine::new(vec![mk(cfg.clone())], route);
            let f = fleet.serve_stream(arrivals.clone(), horizon).unwrap();
            assert!(f.events.iter().all(|e| e.replica == 0), "{label} {route:?}");
            let events: Vec<CbEvent> = f.events.iter().map(|e| e.event.clone()).collect();
            assert_eq!(events, r.events, "{label} {route:?}: streams diverged");
            assert_eq!(f.replicas[0].completed, r.completed, "{label} {route:?}");
            assert_eq!(f.censored(), r.censored, "{label} {route:?}");
            assert_eq!(f.replicas[0].kv_rejected, r.kv_rejected, "{label} {route:?}");
            assert_eq!(f.replicas[0].prefix_hits, r.prefix_hits, "{label} {route:?}");
            assert_eq!(f.replicas[0].windows, r.windows, "{label} {route:?}");
        }
    }
}

#[test]
fn prop_zero_fault_plan_reproduces_fleet_streams() {
    // the chaos layer's identity anchor: a fleet wired with an *empty*
    // FaultPlan must be bit-identical to the same fleet with no plan at
    // all — same events, same counters, same virtual timestamps — over
    // random configs, routes, and truncating horizons. Any fault-path
    // bookkeeping that leaks into the faultless run breaks this.
    let mut rng = Rng::new(4800);
    for case in 0..12 {
        let n = 2 + rng.below(4);
        let t = n * (8 + rng.below(32));
        let shape = TransformerShape::paper_encoder(t);
        let strategy = Strategy::new(StrategyKind::Astra { vq: VqSetting::new(16, 1024) }, n);
        let cfg = CbConfig {
            max_slots: 2 + rng.below(4),
            max_batch: 1 + rng.below(4),
            decode_tokens: 1 + rng.below(16),
            prefill_chunk_tokens: if rng.chance(0.5) { 1 + rng.below(t) } else { 0 },
            prefix_cache: rng.chance(0.5),
            kv_block_tokens: 1 + rng.below(t),
            prompt_groups: rng.below(3),
            seed: rng.next_u64(),
            ..CbConfig::default()
        };
        let mk = |cfg: CbConfig| {
            CbEngine::new(
                shape,
                strategy,
                SimParams::paper_encoder(),
                BandwidthTrace::constant(100.0, 1e9),
                cfg,
            )
        };
        let replicas = 2 + rng.below(2);
        let arrivals = {
            let mut arr = Vec::new();
            let mut at = 0.0;
            for id in 1..=(8 + rng.below(16)) as u64 {
                at += rng.exp(10.0);
                arr.push(Request { id, arrival_s: at, tokens: t });
            }
            arr
        };
        let horizon = 1.0 + rng.f64() * 15.0;
        let route =
            [RouteKind::RoundRobin, RouteKind::LeastLoaded, RouteKind::PrefixAffinity][case % 3];
        let label = format!("case {case}: t={t} replicas={replicas} horizon={horizon:.2}");

        let mut plain = ClusterEngine::new((0..replicas).map(|_| mk(cfg.clone())).collect(), route);
        let p = plain.serve_stream(arrivals.clone(), horizon).unwrap();
        let mut faulted = ClusterEngine::new((0..replicas).map(|_| mk(cfg.clone())).collect(), route)
            .with_faults(FaultPlan::empty());
        let f = faulted.serve_stream(arrivals, horizon).unwrap();

        assert_eq!(f.events, p.events, "{label}: streams diverged under the empty plan");
        assert_eq!(f.completed(), p.completed(), "{label}");
        assert_eq!(f.censored(), p.censored(), "{label}");
        assert_eq!(f.routed, p.routed, "{label}");
        assert!(f.killed.is_empty() && f.restored == 0 && f.replayed == 0, "{label}");
        for (a, b) in f.replicas.iter().zip(p.replicas.iter()) {
            assert_eq!(a.windows, b.windows, "{label}: replica {} windows", a.replica);
            assert_eq!(
                a.latency.p95().to_bits(),
                b.latency.p95().to_bits(),
                "{label}: replica {} latency bits",
                a.replica
            );
        }
    }
}

#[test]
fn prop_reordering_policies_never_starve_saturating_traces() {
    // no-starvation, the aging bound's job: on saturating traces (every
    // request at t=0, generous horizon) both reordering policies must
    // complete every admissible request — nothing is bypassed forever,
    // with or without a KV cap forcing preemption churn
    let mut rng = Rng::new(4600);
    for case in 0..10 {
        let n = 2 + rng.below(3);
        let t = n * (8 + rng.below(32));
        let shape = TransformerShape::paper_encoder(t);
        let strategy = Strategy::new(StrategyKind::Astra { vq: VqSetting::new(16, 1024) }, n);
        let total = 6 + rng.below(10);
        let arrivals: Vec<Request> =
            (0..total as u64).map(|id| Request { id, arrival_s: 0.0, tokens: t }).collect();
        let base = CbConfig {
            max_slots: 2 + rng.below(3),
            max_batch: 1 + rng.below(4),
            decode_tokens: 1 + rng.below(16),
            age_bound_s: 0.05 + rng.f64() * 0.5,
            ..CbConfig::default()
        };
        let mk = |cfg: CbConfig| {
            CbEngine::new(
                shape,
                strategy,
                SimParams::paper_encoder(),
                BandwidthTrace::constant(100.0, 1e9),
                cfg,
            )
        };
        let cap =
            if rng.chance(0.5) { 2 * mk(base.clone()).kv_projection(t) } else { 0 };
        let aware = CbConfig {
            policy: PolicyKind::PrefixAware,
            prefix_cache: true,
            kv_block_tokens: 1 + rng.below(t),
            prompt_groups: 1 + rng.below(3),
            seed: rng.next_u64(),
            kv_cap_bytes: cap,
            ..base.clone()
        };
        let slo = CbConfig {
            policy: PolicyKind::SloClass,
            classes: vec![5.0 + rng.f64() * 20.0, 0.2 + rng.f64()],
            kv_cap_bytes: cap,
            ..base
        };
        for (name, cfg) in [("prefix-aware", aware), ("slo-class", slo)] {
            let r = mk(cfg).serve_stream(arrivals.clone(), 1e6);
            assert_eq!(
                r.completed + r.kv_rejected,
                total,
                "case {case} ({name}, cap={cap}): starved — {} completed, {} rejected, \
                 {} censored of {total}",
                r.completed,
                r.kv_rejected,
                r.censored
            );
            assert_eq!(r.censored, 0, "case {case} ({name})");
        }
    }
}

#[test]
fn prop_latency_monotonic_in_bandwidth() {
    let shape = TransformerShape::paper_encoder(1024);
    let params = SimParams::paper_encoder();
    let mut rng = Rng::new(700);
    for s in astra::parallel::strategies::figure1_strategies(4) {
        let mut prev = f64::INFINITY;
        for bw in [5.0, 10.0, 50.0, 100.0, 500.0, 1000.0] {
            let t = evaluate(&s.schedule(&shape), &params, bw).total();
            assert!(t <= prev + 1e-12, "{} at {bw}", s.name());
            prev = t;
        }
        // compute shrinks with device count
        let n1 = rng.below(3) + 2;
        let n2 = n1 * 2;
        let c1 = evaluate(&Strategy::new(s.kind, n1).schedule(&shape), &params, 1e9).compute_s;
        let c2 = evaluate(&Strategy::new(s.kind, n2).schedule(&shape), &params, 1e9).compute_s;
        if !matches!(s.kind, StrategyKind::SingleDevice) {
            assert!(c2 < c1 + 1e-12, "{}: compute {c1} -> {c2}", s.name());
        }
    }
}

#[test]
fn prop_astra_comm_below_dense_comm() {
    let shape = TransformerShape::paper_encoder(1024);
    let mut rng = Rng::new(800);
    for _ in 0..CASES {
        let g = [1, 2, 4, 8, 16, 32][rng.below(6)];
        let k = [256, 512, 1024, 2048][rng.below(4)];
        let astra = Strategy::new(
            StrategyKind::Astra { vq: VqSetting::new(g, k) }, 4);
        let sp = Strategy::new(StrategyKind::SequenceParallel, 4);
        let a = astra.schedule(&shape).total_comm_bits();
        let s = sp.schedule(&shape).total_comm_bits();
        assert!(a < s / 50.0, "G={g} K={k}: {a} vs {s}");
    }
}

#[test]
fn prop_native_attention_rows_are_convex_combos() {
    // attention output rows lie in the convex hull of V rows (per column)
    let mut rng = Rng::new(900);
    for _ in 0..20 {
        let t = 1 + rng.below(12);
        let s = 1 + rng.below(24);
        let dh = 4 * (1 + rng.below(4));
        let h = 1 + rng.below(2);
        let d = dh * h;
        let mk = |rng: &mut Rng, r: usize| {
            let mut t_ = Tensor::zeros(&[r, d]);
            rng.fill_normal(&mut t_.data);
            t_
        };
        let q = mk(&mut rng, t);
        let k = mk(&mut rng, s);
        let v = mk(&mut rng, s);
        let out = astra::model::native::attention(&q, &k, &v, None, h).unwrap();
        for col in 0..d {
            let (mut lo, mut hi) = (f32::INFINITY, f32::NEG_INFINITY);
            for i in 0..s {
                lo = lo.min(v.row(i)[col]);
                hi = hi.max(v.row(i)[col]);
            }
            for i in 0..t {
                let o = out.row(i)[col];
                assert!(o >= lo - 1e-4 && o <= hi + 1e-4, "col {col}: {o} not in [{lo},{hi}]");
            }
        }
    }
}
