//! Integration tests over the AOT artifacts: PJRT runtime, cluster prefill,
//! cross-backend numerics, packet loss, decode. Require `make artifacts`.

use std::path::{Path, PathBuf};

use astra::config::RunConfig;
use astra::coordinator::{Cluster, ComputeBackend};
use astra::runtime::Artifact;
use astra::tensor::{max_abs_diff, Tensor};
use astra::util::rng::Rng;

fn artifacts_dir() -> Option<PathBuf> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    dir.join("manifest.json").exists().then_some(dir)
}

macro_rules! require_artifacts {
    () => {
        match artifacts_dir() {
            Some(d) => d,
            None => {
                eprintln!("skipping: run `make artifacts` first");
                return;
            }
        }
    };
}

fn synthetic_patches(meta: &astra::runtime::artifact::ModelMeta, seed: u64) -> Tensor {
    let mut rng = Rng::new(seed);
    let mut x = Tensor::zeros(&[meta.seq_len, meta.patch_dim]);
    rng.fill_normal(&mut x.data);
    x
}

#[test]
fn artifact_loads_and_is_consistent() {
    let dir = require_artifacts!();
    let a = Artifact::load(&dir).unwrap();
    assert!(a.graphs.contains_key("astra_block"));
    assert!(a.graphs.contains_key("vq_encode"));
    assert_eq!(a.codebooks.len(), a.meta.n_layers);
    assert_eq!(a.codebooks[0].d_model(), a.meta.d_model);
    // block weights resolvable for every layer
    for li in 0..a.meta.n_layers {
        assert_eq!(a.block_weights(li).unwrap().len(), 16);
    }
}

#[test]
fn native_cluster_prefill_matches_single_device_closely() {
    // VQ approximation error must be bounded: ASTRA logits close to the
    // full-precision baseline (trained codebooks keep the gap small).
    let dir = require_artifacts!();
    let cluster = Cluster::load(&dir, RunConfig::default(), false).unwrap();
    let x = synthetic_patches(&cluster.artifact.meta, 0);
    let out = cluster.prefill(&x).unwrap();
    let (base, _) = cluster.prefill_single_device(&x).unwrap();
    assert_eq!(out.logits.shape, base.shape);
    let denom = base.data.iter().fold(0.0f32, |m, v| m.max(v.abs())).max(1e-6);
    let rel = max_abs_diff(&out.logits, &base) / denom;
    assert!(rel < 1.0, "relative logit deviation {rel}");
    // and the prediction usually agrees
    let argmax = |t: &Tensor| {
        t.data
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0
    };
    // not asserted strictly — VQ can flip a close call — but record it
    eprintln!(
        "astra pred {} vs baseline pred {} (rel dev {rel:.4})",
        argmax(&out.logits),
        argmax(&base)
    );
}

#[test]
fn pjrt_and_native_backends_agree() {
    let dir = require_artifacts!();
    let native = Cluster::load(&dir, RunConfig::default(), false).unwrap();
    let pjrt = Cluster::load(&dir, RunConfig::default(), true).unwrap();
    assert!(matches!(pjrt.backend, ComputeBackend::Pjrt(_)));
    let x = synthetic_patches(&native.artifact.meta, 1);
    let a = native.prefill(&x).unwrap();
    let b = pjrt.prefill(&x).unwrap();
    let diff = max_abs_diff(&a.logits, &b.logits);
    assert!(diff < 1e-3, "native vs PJRT logits differ by {diff}");
    // identical communication accounting regardless of backend
    assert_eq!(a.report.messages, b.report.messages);
    assert_eq!(a.report.payload_bits, b.report.payload_bits);
}

#[test]
fn payload_bits_match_paper_accounting() {
    let dir = require_artifacts!();
    let cluster = Cluster::load(&dir, RunConfig::default(), false).unwrap();
    let meta = &cluster.artifact.meta;
    let x = synthetic_patches(meta, 2);
    let out = cluster.prefill(&x).unwrap();
    // every layer: each device multicasts its T/N tokens to N-1 peers
    let n = meta.n_devices;
    let per_layer = (meta.seq_len / n) * meta.bits_per_token * n * (n - 1);
    let want = (per_layer * meta.n_layers) as f64;
    assert_eq!(out.report.payload_bits, want);
    assert_eq!(out.report.messages, meta.n_layers * n * (n - 1));
    assert_eq!(out.report.bits_per_token_block, meta.bits_per_token as f64);
}

#[test]
fn lower_bandwidth_means_higher_latency() {
    let dir = require_artifacts!();
    let mut cfg = RunConfig::default();
    cfg.bandwidth_mbps = 100.0;
    let fast = Cluster::load(&dir, cfg.clone(), false).unwrap();
    cfg.bandwidth_mbps = 0.1; // pathological
    let slow = Cluster::load(&dir, cfg, false).unwrap();
    let x = synthetic_patches(&fast.artifact.meta, 3);
    let t_fast = fast.prefill(&x).unwrap().report;
    let t_slow = slow.prefill(&x).unwrap().report;
    assert!(t_slow.latency_s > t_fast.latency_s);
    assert!(t_slow.comm_s > t_fast.comm_s);
}

#[test]
fn packet_loss_without_retransmit_degrades_gracefully() {
    let dir = require_artifacts!();
    let mut cfg = RunConfig::default();
    cfg.loss_rate = 0.3; // heavy loss so small payloads actually drop
    cfg.retransmit = false;
    cfg.seed = 7;
    let lossy = Cluster::load(&dir, cfg, false).unwrap();
    let clean = Cluster::load(&dir, RunConfig::default(), false).unwrap();
    let x = synthetic_patches(&clean.artifact.meta, 4);
    let out_clean = clean.prefill(&x).unwrap();
    let out_lossy = lossy.prefill(&x).unwrap();
    // logits remain finite and in a sane range (stale-code fallback)
    assert!(out_lossy.logits.data.iter().all(|v| v.is_finite()));
    let dev = max_abs_diff(&out_clean.logits, &out_lossy.logits);
    eprintln!(
        "loss: {} packets dropped, logit dev {dev}",
        out_lossy.report.packets_dropped
    );
}

#[test]
fn heterogeneous_split_runs_native() {
    let dir = require_artifacts!();
    let mut cfg = RunConfig::default();
    let a = Artifact::load(&dir).unwrap();
    let t = a.meta.seq_len;
    cfg.token_split = vec![t / 2, t / 4, t / 8, t - t / 2 - t / 4 - t / 8];
    let cluster = Cluster::load(&dir, cfg, false).unwrap();
    let x = synthetic_patches(&cluster.artifact.meta, 5);
    let out = cluster.prefill(&x).unwrap();
    // FPAR above the even-split floor of 1/N (Appendix D)
    assert!(out.report.fpar > 0.25);
    assert!(out.logits.data.iter().all(|v| v.is_finite()));
    // PJRT backend must refuse a non-artifact partition
    let mut cfg2 = RunConfig::default();
    cfg2.token_split = vec![t / 2, t / 4, t / 8, t - t / 2 - t / 4 - t / 8];
    assert!(Cluster::load(&dir, cfg2, true).is_err());
}

#[test]
fn hetero_higher_fpar_is_closer_to_baseline() {
    // Appendix D Table 9: more full-precision attention (higher FPAR) ->
    // outputs closer to the full-precision baseline.
    let dir = require_artifacts!();
    let a = Artifact::load(&dir).unwrap();
    let t = a.meta.seq_len;
    let splits = [
        vec![t / 4; 4],                                        // FPAR 0.25
        vec![t / 2, t / 4, t / 8, t - t / 2 - t / 4 - t / 8],  // skewed
        vec![t - 3, 1, 1, 1],                                  // extreme
    ];
    let mut devs = Vec::new();
    for split in &splits {
        let mut cfg = RunConfig::default();
        cfg.token_split = split.clone();
        let cluster = Cluster::load(&dir, cfg, false).unwrap();
        let x = synthetic_patches(&cluster.artifact.meta, 6);
        let out = cluster.prefill(&x).unwrap();
        let (base, _) = cluster.prefill_single_device(&x).unwrap();
        devs.push((out.report.fpar, max_abs_diff(&out.logits, &base)));
    }
    eprintln!("fpar vs logit-dev: {devs:?}");
    // extreme split (FPAR -> 1) strictly better than even split
    assert!(devs[2].1 < devs[0].1, "{devs:?}");
}
