//! Decoder end-to-end: sequence-parallel prefill + autoregressive decode
//! with the mixed KV cache, against a decoder artifact bundle
//! (artifacts-dec/, built by `make artifacts-dec`). Skips when absent.

use std::path::{Path, PathBuf};

use astra::config::RunConfig;
use astra::coordinator::decode::DecodeSession;
use astra::coordinator::Cluster;
use astra::tensor::Tensor;

fn dec_dir() -> Option<PathBuf> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts-dec");
    dir.join("manifest.json").exists().then_some(dir)
}

macro_rules! require_dec {
    () => {
        match dec_dir() {
            Some(d) => d,
            None => {
                eprintln!("skipping: run `make artifacts-dec` first");
                return;
            }
        }
    };
}

#[test]
fn decoder_prefill_runs_and_is_causal() {
    let dir = require_dec!();
    let cluster = Cluster::load(&dir, RunConfig::default(), false).unwrap();
    let meta = &cluster.artifact.meta;
    assert!(meta.causal);
    let t = meta.seq_len;
    let ids: Vec<f32> = (0..t).map(|i| ((i * 7) % meta.vocab_size) as f32).collect();
    let x = Tensor::from_vec(&[t, 1], ids.clone()).unwrap();
    let out = cluster.prefill(&x).unwrap();
    assert_eq!(out.logits.shape, vec![t / meta.n_devices, meta.vocab_size]);

    // causality: changing a *later* token must not change earlier logits.
    // The tail device's first local row is position t - t/N; flip the last
    // token and compare that row.
    let mut ids2 = ids.clone();
    let last = t - 1;
    ids2[last] = ((ids[last] as usize + 1) % meta.vocab_size) as f32;
    let x2 = Tensor::from_vec(&[t, 1], ids2).unwrap();
    let out2 = cluster.prefill(&x2).unwrap();
    let row0_a = out.logits.row(0);
    let row0_b = out2.logits.row(0);
    for (a, b) in row0_a.iter().zip(row0_b.iter()) {
        assert!((a - b).abs() < 1e-4, "future token leaked into the past");
    }
    // ...and the final row must change
    let rl = out.logits.shape[0] - 1;
    let changed = out
        .logits
        .row(rl)
        .iter()
        .zip(out2.logits.row(rl))
        .any(|(a, b)| (a - b).abs() > 1e-6);
    assert!(changed, "last position ignored its own token");
}

#[test]
fn decode_session_generates() {
    let dir = require_dec!();
    let cluster = Cluster::load(&dir, RunConfig::default(), false).unwrap();
    let meta = &cluster.artifact.meta;
    let prompt: Vec<usize> = (0..meta.seq_len).map(|i| (i * 3) % meta.vocab_size).collect();
    let mut sess = DecodeSession::new(&cluster, &prompt).unwrap();
    assert_eq!(sess.len, meta.seq_len);
    let mut toks = Vec::new();
    for _ in 0..8 {
        toks.push(sess.step().unwrap());
    }
    assert_eq!(sess.generated, toks);
    assert!(toks.iter().all(|&t| t < meta.vocab_size));
    assert_eq!(sess.len, meta.seq_len + 8);
    // greedy decode is deterministic: a fresh session reproduces it
    let mut sess2 = DecodeSession::new(&cluster, &prompt).unwrap();
    let again: Vec<usize> = (0..8).map(|_| sess2.step().unwrap()).collect();
    assert_eq!(toks, again);
    // Appendix G: the mixed cache at the session's occupancy (prompt rows
    // mixed-precision, the 8 generated rows full-precision) is smaller
    // than an all-full-precision cache over the same rows
    let full = astra::model::kv_cache_bytes_full(
        &astra::model::TransformerShape {
            n_layers: meta.n_layers,
            d_model: meta.d_model,
            n_heads: meta.n_heads,
            d_ff: meta.d_ff,
            seq_len: meta.seq_len,
            elem_bytes: 4,
        },
        meta.seq_len + 8,
        4,
    );
    assert!(sess.cache_bytes_mixed() < full);
    assert!(sess.cache_bytes_mixed() <= sess.cache_bytes_budget());
}

#[test]
fn first_decode_step_conditions_on_prompt_tail() {
    let dir = require_dec!();
    let cluster = Cluster::load(&dir, RunConfig::default(), false).unwrap();
    let meta = &cluster.artifact.meta;
    // prompt deliberately ending in a non-zero token id
    let tail = 1 + (meta.vocab_size - 1) / 2;
    let mut prompt: Vec<usize> =
        (0..meta.seq_len).map(|i| (i * 3) % meta.vocab_size).collect();
    *prompt.last_mut().unwrap() = tail;
    let sess = DecodeSession::new(&cluster, &prompt).unwrap();
    // the very first step must embed the prompt tail, not token 0
    assert_eq!(sess.conditioning_token(), tail);
    // and that actually matters: the embedding row it selects differs from
    // the token-0 row the old code used
    let embed = cluster.artifact.tensor("embed").unwrap();
    let diff = embed
        .row(tail)
        .iter()
        .zip(embed.row(0))
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    assert!(diff > 1e-6, "embedding rows 0 and {tail} coincide");
    // generations from prompts differing only in the last token diverge in
    // cache state: the two sessions' first steps see different inputs
    let mut a = DecodeSession::new(&cluster, &prompt).unwrap();
    let mut prompt_b = prompt.clone();
    *prompt_b.last_mut().unwrap() = 0;
    let mut b = DecodeSession::new(&cluster, &prompt_b).unwrap();
    assert_ne!(a.conditioning_token(), b.conditioning_token());
    // (argmax may still coincide, so compare conditioning, not tokens)
    let _ = (a.step().unwrap(), b.step().unwrap());
}

#[test]
fn decoder_astra_close_to_baseline() {
    let dir = require_dec!();
    let cluster = Cluster::load(&dir, RunConfig::default(), false).unwrap();
    let meta = &cluster.artifact.meta;
    let t = meta.seq_len;
    let ids: Vec<f32> = (0..t).map(|i| ((i * 11) % meta.vocab_size) as f32).collect();
    let x = Tensor::from_vec(&[t, 1], ids).unwrap();
    let out = cluster.prefill(&x).unwrap();
    let (base, _) = cluster.prefill_single_device(&x).unwrap();
    // compare the tail device's rows against the baseline's final rows
    let tl = t / meta.n_devices;
    let base_tail = base.rows(t - tl, tl).unwrap();
    let rel: f32 = astra::tensor::max_abs_diff(&out.logits, &base_tail)
        / base_tail.data.iter().fold(0.0f32, |m, v| m.max(v.abs())).max(1e-6);
    eprintln!("decoder ASTRA vs baseline tail rows: rel dev {rel}");
    assert!(rel.is_finite());
}
