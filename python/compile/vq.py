"""Codebook learning + Noise-Augmented Vector Quantization (paper §3.2, §3.3).

Build-time only. The rust coordinator consumes the *learned* codebooks
(artifacts/codebooks.bin) and performs encode/decode natively / via the AOT
graphs; nothing here runs on the request path.

Pieces:
  * k-means codebook initialization over intermediate token embeddings
    (paper: "initialized by running K-means clustering over intermediate
    token embeddings from the pretrained model");
  * EMA codebook updates during fine-tuning (VQVAE-style);
  * straight-through estimator for the quantization bottleneck;
  * NAVQ — Gaussian noise fit to the quantization-residual distribution,
    added to quantized embeddings during training (Thm 3.1). We fit a
    diagonal covariance (the paper fits empirical mean/covariance; the
    diagonal restriction matches the i.i.d.-across-dimensions assumption
    its own proof makes in Appendix B Step 2);
  * commitment loss (Eq. 2).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels import ref


def kmeans_init(key, x, g: int, k: int, iters: int = 10):
    """K-means per group over embeddings x [M, D] -> codebook [G, K, D/G].

    Standard Lloyd iterations with dead-centroid re-seeding from random
    points. M should comfortably exceed K.
    """
    m, d = x.shape
    dg = d // g
    assert g * dg == d
    xg = x.reshape(m, g, dg).transpose(1, 0, 2)  # [G, M, Dg]

    def init_one(key, xs):
        idx = jax.random.choice(key, m, (k,), replace=False)
        return xs[idx]

    keys = jax.random.split(key, g)
    cb = jax.vmap(init_one)(keys, xg)  # [G, K, Dg]

    def step(cb, key):
        # assign
        d2 = (
            jnp.sum(xg**2, axis=-1)[:, :, None]
            - 2.0 * jnp.einsum("gmd,gkd->gmk", xg, cb)
            + jnp.sum(cb**2, axis=-1)[:, None, :]
        )  # [G, M, K]
        assign = jnp.argmin(d2, axis=-1)  # [G, M]
        onehot = jax.nn.one_hot(assign, k, dtype=x.dtype)  # [G, M, K]
        counts = jnp.sum(onehot, axis=1)  # [G, K]
        sums = jnp.einsum("gmk,gmd->gkd", onehot, xg)
        new = sums / jnp.maximum(counts, 1.0)[:, :, None]
        # re-seed dead centroids from random data points
        rand_pts = xg[:, jax.random.randint(key, (k,), 0, m), :]
        dead = (counts < 0.5)[:, :, None]
        return jnp.where(dead, rand_pts, new), None

    step_keys = jax.random.split(jax.random.fold_in(key, 1), iters)
    cb, _ = jax.lax.scan(step, cb, step_keys)
    return cb


def ema_update(cb, counts_ema, sums_ema, x, decay: float = 0.99, eps: float = 1e-5):
    """VQVAE-style EMA codebook update from a batch of embeddings x [M, D].

    Returns (new_cb, new_counts_ema, new_sums_ema). Laplace-smoothed so rare
    codes do not collapse to zero.
    """
    g, k, dg = cb.shape
    m = x.shape[0]
    xg = x.reshape(m, g, dg).transpose(1, 0, 2)
    idx = ref.ref_grouped_vq_encode(x, cb)  # [M, G]
    onehot = jax.nn.one_hot(idx.transpose(1, 0), k, dtype=x.dtype)  # [G, M, K]
    counts = jnp.sum(onehot, axis=1)  # [G, K]
    sums = jnp.einsum("gmk,gmd->gkd", onehot, xg)  # [G, K, Dg]
    counts_ema = decay * counts_ema + (1 - decay) * counts
    sums_ema = decay * sums_ema + (1 - decay) * sums
    n = jnp.sum(counts_ema, axis=-1, keepdims=True)
    stable = (counts_ema + eps) / (n + k * eps) * n  # Laplace smoothing
    new_cb = sums_ema / stable[:, :, None]
    # keep old centroid where a code has (numerically) never been used
    never = (counts_ema < 1e-3)[:, :, None]
    return jnp.where(never, cb, new_cb), counts_ema, sums_ema


def straight_through(x, x_hat):
    """Quantize with identity gradient (VQVAE straight-through estimator)."""
    return x + jax.lax.stop_gradient(x_hat - x)


def fit_residual_noise(x, x_hat):
    """Empirical mean/std of the quantization residual eps = X - X_hat.

    Returns (mu [D], sigma [D]) — the distribution NAVQ samples from.
    """
    eps = x - x_hat
    mu = jnp.mean(eps, axis=0)
    sigma = jnp.sqrt(jnp.mean((eps - mu) ** 2, axis=0) + 1e-12)
    return mu, sigma


def navq(key, x, codebook, lam: float):
    """Noise-Augmented Vector Quantization (training path).

    Returns (x_tilde, x_hat, commit) where
      x_tilde = ST(x_hat) + lam * xi,  xi ~ N(mu, diag(sigma^2)) fit on the
                residuals of this batch (stop-gradient through the noise);
      commit  = || x - sg(x_hat) ||^2 mean — the Eq. 2 commitment term.
    At inference (lam irrelevant) use the deterministic roundtrip instead.
    """
    x_hat = ref.ref_grouped_vq_roundtrip(x, codebook)
    mu, sigma = fit_residual_noise(x, x_hat)
    xi = mu + sigma * jax.random.normal(key, x.shape, x.dtype)
    x_tilde = straight_through(x, x_hat) + lam * jax.lax.stop_gradient(xi)
    commit = jnp.mean(jnp.sum((x - jax.lax.stop_gradient(x_hat)) ** 2, axis=-1))
    return x_tilde, x_hat, commit


def codebook_utilization(indices, k: int):
    """Fraction of codes used at least once. indices [.., G] int32."""
    flat = indices.reshape(-1)
    used = jnp.zeros((k,), jnp.int32).at[flat].set(1)
    return jnp.mean(used.astype(jnp.float32))
