"""L2: the AstraFormer model — JAX fwd for training/eval + per-device AOT graphs.

Two views of the same parameters:

  * `astra_forward` — the *joint* training/eval graph: all N devices'
    computation expressed in one program. Mixed-Precision Attention is
    expressed exactly as paper Eq. 1: queries attend over 2·T' columns,
    [ X (full precision) | X_hat (vector-quantized) ], with an additive
    mask M that admits full-precision columns only for same-device pairs
    and quantized columns only for cross-device pairs. This is what
    fine-tuning (train.py) differentiates through.

  * `build_*` graph builders — the per-device inference graphs the rust
    coordinator actually runs (one AOT HLO per graph, weights as runtime
    buffers): embed, vq_encode, vq_decode, astra_block (device-local MPA
    block), baseline_block (full-precision single-device block), head,
    decode_step. These call the L1 Pallas kernels so the kernels lower
    into the same HLO artifact.

Distributed Class Tokens (§3.3): the CLS token is replicated once per
device; replica d is a *local* token of device d as a query, but is never
attended as a key and never transmitted (so comm accounting counts content
tokens only). Replicas are mean-pooled before the prediction head.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from . import vq as vqlib
from .kernels import mixed_attention as mak
from .kernels import ref
from .kernels import vq_kernels as vqk

NEG = -1e30


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Architecture of one AstraFormer."""

    n_layers: int = 4
    d_model: int = 128
    n_heads: int = 4
    d_ff: int = 512
    seq_len: int = 64          # content tokens T
    causal: bool = False       # decoder (GPT-ish) vs encoder (ViT-ish)
    use_cls: bool = True       # encoder classification
    vocab_size: int = 64       # decoder vocabulary
    patch_dim: int = 48        # encoder input patch feature size
    n_classes: int = 16        # encoder classes

    @property
    def d_head(self) -> int:
        return self.d_model // self.n_heads


@dataclasses.dataclass(frozen=True)
class AstraConfig:
    """ASTRA deployment/compression settings."""

    n_devices: int = 4
    groups: int = 16
    codebook_size: int = 64
    noise_lambda: float = 1.0   # NAVQ lambda
    commit_beta: float = 2e-4   # Eq. 2 beta

    @property
    def bits_per_token(self) -> int:
        """VQ code payload for one transmitted token: G * ceil(log2 K)."""
        import math

        return self.groups * math.ceil(math.log2(self.codebook_size))


# --------------------------------------------------------------------------
# parameters
# --------------------------------------------------------------------------


def init_params(key, cfg: ModelConfig) -> dict[str, Any]:
    """Xavier-ish init; returns a nested dict pytree."""
    d, f = cfg.d_model, cfg.d_ff
    ks = iter(jax.random.split(key, 6 + 16 * cfg.n_layers))

    def dense(key, din, dout):
        return jax.random.normal(key, (din, dout), jnp.float32) * (din**-0.5)

    params: dict[str, Any] = {
        "pos": jax.random.normal(next(ks), (cfg.seq_len, d), jnp.float32) * 0.02,
        "ln_f": {"g": jnp.ones((d,)), "b": jnp.zeros((d,))},
    }
    if cfg.causal:
        params["embed"] = jax.random.normal(next(ks), (cfg.vocab_size, d)) * 0.02
        params["head"] = {"w": dense(next(ks), d, cfg.vocab_size), "b": jnp.zeros((cfg.vocab_size,))}
    else:
        params["embed"] = {"w": dense(next(ks), cfg.patch_dim, d), "b": jnp.zeros((d,))}
        params["cls"] = jax.random.normal(next(ks), (1, d)) * 0.02
        params["head"] = {"w": dense(next(ks), d, cfg.n_classes), "b": jnp.zeros((cfg.n_classes,))}
    blocks = []
    for _ in range(cfg.n_layers):
        blocks.append(
            {
                "ln1": {"g": jnp.ones((d,)), "b": jnp.zeros((d,))},
                "wq": dense(next(ks), d, d),
                "wk": dense(next(ks), d, d),
                "wv": dense(next(ks), d, d),
                "wo": dense(next(ks), d, d),
                "bq": jnp.zeros((d,)),
                "bk": jnp.zeros((d,)),
                "bv": jnp.zeros((d,)),
                "bo": jnp.zeros((d,)),
                "ln2": {"g": jnp.ones((d,)), "b": jnp.zeros((d,))},
                "w1": dense(next(ks), d, f),
                "b1": jnp.zeros((f,)),
                "w2": dense(next(ks), f, d),
                "b2": jnp.zeros((d,)),
            }
        )
    params["blocks"] = blocks
    return params


def init_codebooks(key, cfg: ModelConfig, acfg: AstraConfig):
    """Random-normal codebooks [L, G, K, Dg]; train.py replaces with k-means."""
    dg = cfg.d_model // acfg.groups
    return (
        jax.random.normal(
            key, (cfg.n_layers, acfg.groups, acfg.codebook_size, dg), jnp.float32
        )
        * 0.5
    )


# --------------------------------------------------------------------------
# shared pieces
# --------------------------------------------------------------------------


def _split_heads(x, h):
    t, d = x.shape
    return x.reshape(t, h, d // h).transpose(1, 0, 2)  # [H, T, dh]


def _merge_heads(x):
    h, t, dh = x.shape
    return x.transpose(1, 0, 2).reshape(t, h * dh)


def _attn_jnp(q, k, v, bias):
    return ref.ref_attention(q, k, v, bias)


def _project_qkv(blk, x_norm):
    q = x_norm @ blk["wq"] + blk["bq"]
    k = x_norm @ blk["wk"] + blk["bk"]
    v = x_norm @ blk["wv"] + blk["bv"]
    return q, k, v


def _mlp(blk, x):
    return ref.ref_mlp(
        ref.ref_layer_norm(x, blk["ln2"]["g"], blk["ln2"]["b"]),
        blk["w1"], blk["b1"], blk["w2"], blk["b2"],
    )


# --------------------------------------------------------------------------
# joint (training / eval) ASTRA forward
# --------------------------------------------------------------------------


def make_assign(cfg: ModelConfig, acfg: AstraConfig, sizes=None):
    """Device assignment for the T content tokens.

    Decoder: contiguous chunks (sequence parallel prefill). Encoder: default
    even contiguous split; `sizes` (len N, sums to T) gives heterogeneous
    splits. Returns int32 [T].
    """
    t, n = cfg.seq_len, acfg.n_devices
    if sizes is None:
        assert t % n == 0, f"T={t} not divisible by N={n}"
        sizes = [t // n] * n
    assert sum(sizes) == t
    return jnp.concatenate(
        [jnp.full((s,), i, jnp.int32) for i, s in enumerate(sizes)]
    )


def fpar(assign, n_devices: int) -> jnp.ndarray:
    """Full-Precision Attention Rate (Appendix D Eq. 35)."""
    t = assign.shape[0]
    counts = jnp.bincount(assign, length=n_devices)
    return jnp.sum((counts / t) ** 2)


def mixed_bias(cfg: ModelConfig, acfg: AstraConfig, assign):
    """Additive mask for the joint 2-column-block attention.

    Queries: N CLS replicas (encoder) followed by T content tokens.
    Keys: [ full(T') | hat(T) ] where T' = N_cls + T; CLS replicas are
    included as full-precision keys only for *their own device's* queries
    and are excluded from the hat block entirely (never transmitted).
    Returns bias [Tq, T' + T] with 0 = allowed, NEG = masked.
    """
    t = cfg.seq_len
    n = acfg.n_devices
    ncls = n if (cfg.use_cls and not cfg.causal) else 0
    q_dev = jnp.concatenate([jnp.arange(ncls, dtype=jnp.int32), assign])
    same = q_dev[:, None] == q_dev[None, :]
    is_cls_key = jnp.arange(ncls + t) < ncls
    # CLS keys visible only to same-device queries (which `same` already
    # encodes); content keys visible to same-device queries.
    full_ok = same
    hat_ok = q_dev[:, None] != assign[None, :]
    if cfg.causal:
        pos = jnp.arange(t)
        causal_ok = pos[None, :] <= pos[:, None]
        full_ok = full_ok & causal_ok
        hat_ok = hat_ok & causal_ok
    del is_cls_key
    bias = jnp.concatenate(
        [jnp.where(full_ok, 0.0, NEG), jnp.where(hat_ok, 0.0, NEG)], axis=1
    )
    return bias.astype(jnp.float32)


def _embed(params, cfg: ModelConfig, x):
    if cfg.causal:
        h = params["embed"][x] + params["pos"]
    else:
        h = x @ params["embed"]["w"] + params["embed"]["b"] + params["pos"]
    return h


def astra_forward(
    params,
    codebooks,
    x,
    cfg: ModelConfig,
    acfg: AstraConfig,
    assign=None,
    *,
    train: bool = False,
    rng=None,
    use_pallas: bool = False,
):
    """Joint multi-device ASTRA forward.

    x: encoder [T, patch_dim] float32; decoder [T] int32 token ids.
    Returns (outputs, aux) where outputs = logits ([n_classes] encoder,
    [T, vocab] decoder) and aux carries the commitment loss and per-layer
    codebook inputs (for EMA updates).
    """
    if assign is None:
        assign = make_assign(cfg, acfg)
    n = acfg.n_devices
    ncls = n if (cfg.use_cls and not cfg.causal) else 0
    h_tok = _embed(params, cfg, x)  # [T, D]
    if ncls:
        h = jnp.concatenate([jnp.tile(params["cls"], (n, 1)), h_tok], axis=0)
    else:
        h = h_tok
    bias = mixed_bias(cfg, acfg, assign)
    attn = mak.attention if use_pallas else _attn_jnp

    commit = 0.0
    vq_inputs = []
    for li, blk in enumerate(params["blocks"]):
        content = h[ncls:]  # only content tokens are quantized/transmitted
        vq_inputs.append(content)
        if train:
            rng, sub = jax.random.split(rng)
            x_tilde, _, c = vqlib.navq(sub, content, codebooks[li], acfg.noise_lambda)
            commit = commit + c
        else:
            x_tilde = ref.ref_grouped_vq_roundtrip(content, codebooks[li])
        ln1 = lambda y: ref.ref_layer_norm(y, blk["ln1"]["g"], blk["ln1"]["b"])
        q, k_full, v_full = _project_qkv(blk, ln1(h))
        _, k_hat, v_hat = _project_qkv(blk, ln1(x_tilde))
        hh = cfg.n_heads
        out = attn(
            _split_heads(q, hh),
            jnp.concatenate([_split_heads(k_full, hh), _split_heads(k_hat, hh)], axis=1),
            jnp.concatenate([_split_heads(v_full, hh), _split_heads(v_hat, hh)], axis=1),
            bias,
        )
        h = h + _merge_heads(out) @ blk["wo"] + blk["bo"]
        h = h + _mlp(blk, h)

    aux = {"commit": commit, "vq_inputs": vq_inputs}
    lnf = lambda y: ref.ref_layer_norm(y, params["ln_f"]["g"], params["ln_f"]["b"])
    if ncls:
        pooled = jnp.mean(h[:ncls], axis=0)  # Distributed Class Token pooling
        return lnf(pooled) @ params["head"]["w"] + params["head"]["b"], aux
    logits = lnf(h) @ params["head"]["w"] + params["head"]["b"]
    return logits, aux


def astra_forward_single_cls(
    params, codebooks, x, cfg: ModelConfig, acfg: AstraConfig, assign=None
):
    """Ablation: a single class token living on device 0 (Table 13 baseline).

    The lone CLS sees device-0 tokens full precision and every other
    device's tokens only through their VQ codes — the information asymmetry
    Distributed Class Tokens remove.
    """
    if assign is None:
        assign = make_assign(cfg, acfg)
    t = cfg.seq_len
    h_tok = _embed(params, cfg, x)
    h = jnp.concatenate([params["cls"], h_tok], axis=0)
    q_dev = jnp.concatenate([jnp.zeros((1,), jnp.int32), assign])
    same = q_dev[:, None] == q_dev[None, :]
    hat_ok = q_dev[:, None] != assign[None, :]
    bias = jnp.concatenate(
        [jnp.where(same, 0.0, NEG), jnp.where(hat_ok, 0.0, NEG)], axis=1
    ).astype(jnp.float32)

    for li, blk in enumerate(params["blocks"]):
        content = h[1:]
        x_hat = ref.ref_grouped_vq_roundtrip(content, codebooks[li])
        ln1 = lambda y: ref.ref_layer_norm(y, blk["ln1"]["g"], blk["ln1"]["b"])
        q, k_full, v_full = _project_qkv(blk, ln1(h))
        _, k_hat, v_hat = _project_qkv(blk, ln1(x_hat))
        hh = cfg.n_heads
        out = _attn_jnp(
            _split_heads(q, hh),
            jnp.concatenate([_split_heads(k_full, hh), _split_heads(k_hat, hh)], axis=1),
            jnp.concatenate([_split_heads(v_full, hh), _split_heads(v_hat, hh)], axis=1),
            bias,
        )
        h = h + _merge_heads(out) @ blk["wo"] + blk["bo"]
        h = h + _mlp(blk, h)
    lnf = lambda y: ref.ref_layer_norm(y, params["ln_f"]["g"], params["ln_f"]["b"])
    return lnf(h[0]) @ params["head"]["w"] + params["head"]["b"]


# --------------------------------------------------------------------------
# single-device reference forward (the "Original Model" baseline)
# --------------------------------------------------------------------------


def reference_forward(params, x, cfg: ModelConfig, *, use_pallas: bool = False):
    """Full-precision single-device forward; logits as in astra_forward."""
    h_tok = _embed(params, cfg, x)
    ncls = 1 if (cfg.use_cls and not cfg.causal) else 0
    h = jnp.concatenate([params["cls"], h_tok], axis=0) if ncls else h_tok
    t_all = h.shape[0]
    if cfg.causal:
        pos = jnp.arange(t_all)
        bias = jnp.where(pos[None, :] <= pos[:, None], 0.0, NEG).astype(jnp.float32)
    else:
        bias = jnp.zeros((t_all, t_all), jnp.float32)
    attn = mak.attention if use_pallas else _attn_jnp
    for blk in params["blocks"]:
        ln1 = lambda y: ref.ref_layer_norm(y, blk["ln1"]["g"], blk["ln1"]["b"])
        q, k, v = _project_qkv(blk, ln1(h))
        hh = cfg.n_heads
        out = attn(_split_heads(q, hh), _split_heads(k, hh), _split_heads(v, hh), bias)
        h = h + _merge_heads(out) @ blk["wo"] + blk["bo"]
        h = h + _mlp(blk, h)
    lnf = lambda y: ref.ref_layer_norm(y, params["ln_f"]["g"], params["ln_f"]["b"])
    if ncls:
        return lnf(h[0]) @ params["head"]["w"] + params["head"]["b"]
    return lnf(h) @ params["head"]["w"] + params["head"]["b"]


# --------------------------------------------------------------------------
# per-device AOT graph builders (lowered to HLO by aot.py)
# --------------------------------------------------------------------------
# Weight arguments are flat, fixed-order lists so the rust side can bind
# uploaded device buffers positionally. Order must match BLOCK_WEIGHT_NAMES.

BLOCK_WEIGHT_NAMES = [
    "ln1.g", "ln1.b", "wq", "bq", "wk", "bk", "wv", "bv", "wo", "bo",
    "ln2.g", "ln2.b", "w1", "b1", "w2", "b2",
]


def block_weights_list(blk):
    return [
        blk["ln1"]["g"], blk["ln1"]["b"],
        blk["wq"], blk["bq"], blk["wk"], blk["bk"], blk["wv"], blk["bv"],
        blk["wo"], blk["bo"],
        blk["ln2"]["g"], blk["ln2"]["b"],
        blk["w1"], blk["b1"], blk["w2"], blk["b2"],
    ]


def _blk_from_list(ws):
    n = dict(zip(BLOCK_WEIGHT_NAMES, ws))
    return {
        "ln1": {"g": n["ln1.g"], "b": n["ln1.b"]},
        "wq": n["wq"], "bq": n["bq"], "wk": n["wk"], "bk": n["bk"],
        "wv": n["wv"], "bv": n["bv"], "wo": n["wo"], "bo": n["bo"],
        "ln2": {"g": n["ln2.g"], "b": n["ln2.b"]},
        "w1": n["w1"], "b1": n["b1"], "w2": n["w2"], "b2": n["b2"],
    }


def astra_block_device(h_local, x_hat_remote, bias, *ws, n_heads: int, use_pallas: bool = True):
    """One MPA transformer block on one device.

    h_local:      [Tl, D] full-precision local rows (CLS replica first, enc)
    x_hat_remote: [Tr, D] dequantized non-local token embeddings
    bias:         [Tl, Tl+Tr] additive mask (local/remote/causal structure,
                  computed by the rust partitioner)
    Returns new h_local [Tl, D].
    """
    blk = _blk_from_list(ws)
    ln1 = lambda y: ref.ref_layer_norm(y, blk["ln1"]["g"], blk["ln1"]["b"])
    q, k_l, v_l = _project_qkv(blk, ln1(h_local))
    _, k_r, v_r = _project_qkv(blk, ln1(x_hat_remote))
    hh = n_heads
    attn = mak.mixed_attention if use_pallas else (
        lambda q, kl, vl, kr, vr, b: ref.ref_mixed_attention(q, kl, vl, kr, vr, b)
    )
    out = attn(
        _split_heads(q, hh),
        _split_heads(k_l, hh), _split_heads(v_l, hh),
        _split_heads(k_r, hh), _split_heads(v_r, hh),
        bias,
    )
    h = h_local + _merge_heads(out) @ blk["wo"] + blk["bo"]
    return h + _mlp(blk, h)


def baseline_block(h, bias, *ws, n_heads: int, use_pallas: bool = True):
    """Full-precision block over the whole sequence (single-device baseline,
    and the numeric ground truth the rust runtime is cross-checked against)."""
    blk = _blk_from_list(ws)
    ln1 = lambda y: ref.ref_layer_norm(y, blk["ln1"]["g"], blk["ln1"]["b"])
    q, k, v = _project_qkv(blk, ln1(h))
    hh = n_heads
    attn = mak.attention if use_pallas else _attn_jnp
    out = attn(_split_heads(q, hh), _split_heads(k, hh), _split_heads(v, hh), bias)
    h = h + _merge_heads(out) @ blk["wo"] + blk["bo"]
    return h + _mlp(blk, h)


def vq_encode_graph(x, codebook, *, use_pallas: bool = True):
    """[Tc, D] + [G, K, Dg] -> int32 [Tc, G]."""
    f = vqk.grouped_vq_encode if use_pallas else ref.ref_grouped_vq_encode
    return f(x, codebook)


def vq_decode_graph(idx, codebook, *, use_pallas: bool = True):
    """int32 [Tr, G] + [G, K, Dg] -> [Tr, D]."""
    f = vqk.grouped_vq_decode if use_pallas else ref.ref_grouped_vq_decode
    return f(idx, codebook)


def embed_enc_graph(patches, w, b, pos):
    """[T, P] -> content token embeddings [T, D] (CLS handled by the leader)."""
    return patches @ w + b + pos


def embed_dec_graph(onehot_ids, embed, pos):
    """One-hot ids [T, V] -> [T, D]. (Rust builds the one-hot; a dense
    matmul keeps the graph gather-free, cf. the VQ decode kernel.)"""
    return onehot_ids @ embed + pos


def head_graph(cls_stack, g, b, w, bh):
    """Distributed CLS aggregation: [N, D] -> mean-pool -> LN -> logits."""
    pooled = jnp.mean(cls_stack, axis=0)
    return ref.ref_layer_norm(pooled, g, b) @ w + bh


def lm_head_graph(h, g, b, w, bh):
    """Decoder head: [Tl, D] -> LN -> logits [Tl, V]."""
    return ref.ref_layer_norm(h, g, b) @ w + bh


def decode_step_block(h_t, k_cache, v_cache, valid, *ws, n_heads: int):
    """Autoregressive decode, one block, one new token (runs on the device
    owning the sequence tail; non-local cache rows were dequantized from VQ
    codes — Appendix G's mixed KV cache).

    h_t: [1, D]; k_cache/v_cache: [H, S, dh] (rows beyond the current length
    are garbage); valid: [S] {0,1} float mask. Returns (h_out [1, D],
    k_new [H, 1, dh], v_new [H, 1, dh]) — rust writes k/v_new into the cache.
    """
    blk = _blk_from_list(ws)
    ln1 = lambda y: ref.ref_layer_norm(y, blk["ln1"]["g"], blk["ln1"]["b"])
    q, k_t, v_t = _project_qkv(blk, ln1(h_t))
    hh = n_heads
    qh = _split_heads(q, hh)        # [H, 1, dh]
    k_new = _split_heads(k_t, hh)   # [H, 1, dh]
    v_new = _split_heads(v_t, hh)
    k_all = jnp.concatenate([k_cache, k_new], axis=1)  # [H, S+1, dh]
    v_all = jnp.concatenate([v_cache, v_new], axis=1)
    bias = jnp.concatenate([jnp.where(valid > 0.5, 0.0, NEG), jnp.zeros((1,))])[None, :]
    out = _attn_jnp(qh, k_all, v_all, bias.astype(jnp.float32))
    h = h_t + _merge_heads(out) @ blk["wo"] + blk["bo"]
    return h + _mlp(blk, h), k_new, v_new
