"""Build-time fine-tuning harness (paper §4.1, Appendix D).

Pipeline per the paper: start from a trained full-precision model, insert
the VQ bottlenecks, initialize codebooks with k-means over intermediate
token embeddings, then fine-tune with task loss + commitment loss (Eq. 2),
NAVQ noise (§3.3) and EMA codebook updates.

Because no pretrained checkpoints exist in this environment, "pretraining"
is itself a (short) run of the same harness with the reference model; the
accuracy tables in EXPERIMENTS.md then compare reference vs ASTRA variants
exactly as the paper compares original vs ASTRA rows.

Everything here is build-time python; optimizers are hand-rolled (no optax
in the image).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp

from . import datasets, model, vq as vqlib


# ----------------------------------------------------------------------
# hand-rolled Adam
# ----------------------------------------------------------------------


def adam_init(params):
    zeros = jax.tree.map(jnp.zeros_like, params)
    return {"m": zeros, "v": jax.tree.map(jnp.zeros_like, params), "t": jnp.zeros((), jnp.int32)}


def adam_update(grads, state, params, lr, b1=0.9, b2=0.999, eps=1e-8):
    t = state["t"] + 1
    m = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, state["m"], grads)
    v = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * g * g, state["v"], grads)
    mh = jax.tree.map(lambda m: m / (1 - b1 ** t.astype(jnp.float32)), m)
    vh = jax.tree.map(lambda v: v / (1 - b2 ** t.astype(jnp.float32)), v)
    new = jax.tree.map(lambda p, mh, vh: p - lr * mh / (jnp.sqrt(vh) + eps), params, mh, vh)
    return new, {"m": m, "v": v, "t": t}


# ----------------------------------------------------------------------
# losses
# ----------------------------------------------------------------------


def xent(logits, y):
    logz = jax.nn.logsumexp(logits, axis=-1)
    return jnp.mean(logz - jnp.take_along_axis(logits, y[..., None], axis=-1)[..., 0])


def accuracy(logits, y):
    return jnp.mean((jnp.argmax(logits, axis=-1) == y).astype(jnp.float32))


# ----------------------------------------------------------------------
# reference pretraining
# ----------------------------------------------------------------------


@dataclasses.dataclass
class TrainResult:
    params: Any
    codebooks: Any
    metrics: dict


def _batched(fn, *in_axes):
    return jax.vmap(fn, in_axes=in_axes)


def pretrain_reference(key, cfg: model.ModelConfig, data_fn: Callable, *, steps=300, batch=32, lr=1e-3, eval_fn=None, log_every=0):
    """Train the full-precision reference model on the synthetic task."""
    kp, kd = jax.random.split(key)
    params = model.init_params(kp, cfg)
    opt = adam_init(params)

    fwd = _batched(lambda p, x: model.reference_forward(p, x, cfg), None, 0)

    if cfg.causal:
        def loss_fn(p, xb, yb):
            logits = fwd(p, xb)
            return xent(logits, yb)
    else:
        def loss_fn(p, xb, yb):
            logits = fwd(p, xb)
            return xent(logits, yb)

    @jax.jit
    def step(p, o, xb, yb):
        l, g = jax.value_and_grad(loss_fn)(p, xb, yb)
        p, o = adam_update(g, o, p, lr)
        return p, o, l

    last = None
    for i in range(steps):
        kd, kb = jax.random.split(kd)
        xb, yb = data_fn(kb, batch)
        params, opt, last = step(params, opt, xb, yb)
        if log_every and i % log_every == 0:
            print(f"  ref step {i}: loss {float(last):.4f}")
    return TrainResult(params, None, {"final_loss": float(last)})


# ----------------------------------------------------------------------
# ASTRA fine-tuning
# ----------------------------------------------------------------------


def collect_embeddings(key, params, cfg, acfg, data_fn, n_batches=4, batch=16):
    """Run the reference model and harvest per-layer block inputs for k-means."""
    # identity codebooks are not needed: run astra_forward in eval mode with
    # a huge-noise-free roundtrip replaced by identity — easiest is to reuse
    # reference_forward internals; instead we grab vq_inputs from
    # astra_forward with codebooks=None path below.
    outs = [[] for _ in range(cfg.n_layers)]
    # Temporary "codebooks" that make roundtrip ~identity are impossible;
    # instead collect from a forward pass that skips quantization: reuse
    # astra_forward with train=False but patch roundtrip via identity cb is
    # messy — simply run the reference blocks manually here.
    def harvest(x):
        h_tok = model._embed(params, cfg, x)
        ncls = acfg.n_devices if (cfg.use_cls and not cfg.causal) else 0
        if ncls:
            h = jnp.concatenate([jnp.tile(params["cls"], (acfg.n_devices, 1)), h_tok], axis=0)
        else:
            h = h_tok
        t_all = h.shape[0]
        if cfg.causal:
            pos = jnp.arange(t_all)
            bias = jnp.where(pos[None, :] <= pos[:, None], 0.0, model.NEG).astype(jnp.float32)
        else:
            bias = jnp.zeros((t_all, t_all), jnp.float32)
        per_layer = []
        for blk in params["blocks"]:
            per_layer.append(h[ncls:])
            h = model.baseline_block(h, bias, *model.block_weights_list(blk), n_heads=cfg.n_heads, use_pallas=False)
        return per_layer

    hv = jax.jit(jax.vmap(harvest))
    for i in range(n_batches):
        key, kb = jax.random.split(key)
        xb, _ = data_fn(kb, batch)
        per_layer = hv(xb)
        for li in range(cfg.n_layers):
            outs[li].append(per_layer[li].reshape(-1, cfg.d_model))
    return [jnp.concatenate(o, axis=0) for o in outs]


def kmeans_codebooks(key, embeddings, acfg):
    """Per-layer k-means init — paper §3.2."""
    cbs = []
    for li, emb in enumerate(embeddings):
        k = jax.random.fold_in(key, li)
        # subsample for speed
        m = min(emb.shape[0], 2048)
        idx = jax.random.choice(k, emb.shape[0], (m,), replace=False)
        cbs.append(vqlib.kmeans_init(k, emb[idx], acfg.groups, acfg.codebook_size))
    return jnp.stack(cbs)  # [L, G, K, Dg]


def finetune_astra(
    key,
    pretrained,
    cfg: model.ModelConfig,
    acfg: model.AstraConfig,
    data_fn,
    *,
    steps=300,
    batch=32,
    lr=5e-4,
    single_cls: bool = False,
    random_assign: bool = False,
    ema_codebooks: bool = True,
    log_every=0,
):
    """Insert VQ, k-means-init codebooks, fine-tune with Eq. 2 + NAVQ.

    random_assign=True trains with a randomized token-to-device mapping per
    batch (the paper's recipe for heterogeneity generalization, App. D).
    """
    k0, k1, kd = jax.random.split(key, 3)
    params = pretrained
    emb = collect_embeddings(k0, params, cfg, acfg, data_fn)
    codebooks = kmeans_codebooks(k1, emb, acfg)
    opt = adam_init(params)
    counts = jnp.zeros((cfg.n_layers, acfg.groups, acfg.codebook_size))
    sums = jnp.zeros_like(codebooks)

    even = model.make_assign(cfg, acfg)

    def fwd_one(p, cb, x, assign, rng):
        if single_cls:
            logits = model.astra_forward_single_cls(p, cb, x, cfg, acfg, assign)
            return logits, jnp.zeros(()), [jnp.zeros((cfg.seq_len, cfg.d_model))] * cfg.n_layers
        logits, aux = model.astra_forward(
            p, cb, x, cfg, acfg, assign, train=True, rng=rng
        )
        return logits, aux["commit"], aux["vq_inputs"]

    def loss_fn(p, cb, xb, yb, assign, rngs):
        logits, commit, vq_in = jax.vmap(
            fwd_one, in_axes=(None, None, 0, None, 0)
        )(p, cb, xb, assign, rngs)
        return xent(logits, yb) + acfg.commit_beta * jnp.mean(commit), vq_in

    @jax.jit
    def step(p, o, cb, cnt, sm, xb, yb, assign, rng):
        rngs = jax.random.split(rng, xb.shape[0])
        (l, vq_in), g = jax.value_and_grad(loss_fn, has_aux=True)(p, cb, xb, yb, assign, rngs)
        p, o = adam_update(g, o, p, lr)
        if ema_codebooks and not single_cls:
            new_cb, new_cnt, new_sm = [], [], []
            for li in range(cfg.n_layers):
                flat = vq_in[li].reshape(-1, cfg.d_model)
                c, ct, s = vqlib.ema_update(cb[li], cnt[li], sm[li], flat)
                new_cb.append(c); new_cnt.append(ct); new_sm.append(s)
            cb = jnp.stack(new_cb); cnt = jnp.stack(new_cnt); sm = jnp.stack(new_sm)
        return p, o, cb, cnt, sm, l

    last = None
    for i in range(steps):
        kd, kb, ka, kr = jax.random.split(kd, 4)
        xb, yb = data_fn(kb, batch)
        if random_assign:
            assign = jax.random.randint(ka, (cfg.seq_len,), 0, acfg.n_devices).astype(jnp.int32)
        else:
            assign = even
        params, opt, codebooks, counts, sums, last = step(
            params, opt, codebooks, counts, sums, xb, yb, assign, kr
        )
        if log_every and i % log_every == 0:
            print(f"  astra step {i}: loss {float(last):.4f}")
    return TrainResult(params, codebooks, {"final_loss": float(last)})


# ----------------------------------------------------------------------
# evaluation
# ----------------------------------------------------------------------


def eval_reference(params, cfg, data_fn, key, *, n_batches=8, batch=32):
    fwd = jax.jit(jax.vmap(lambda x: model.reference_forward(params, x, cfg)))
    return _eval_loop(fwd, cfg, data_fn, key, n_batches, batch)


def eval_astra(params, codebooks, cfg, acfg, data_fn, key, *, assign=None, n_batches=8, batch=32, single_cls=False):
    if single_cls:
        f = lambda x: model.astra_forward_single_cls(params, codebooks, x, cfg, acfg, assign)
    else:
        f = lambda x: model.astra_forward(params, codebooks, x, cfg, acfg, assign)[0]
    fwd = jax.jit(jax.vmap(f))
    return _eval_loop(fwd, cfg, data_fn, key, n_batches, batch)


def _eval_loop(fwd, cfg, data_fn, key, n_batches, batch):
    """Returns {'acc', 'loss', 'ppl'} averaged over n_batches."""
    accs, losses = [], []
    for _ in range(n_batches):
        key, kb = jax.random.split(key)
        xb, yb = data_fn(kb, batch)
        logits = fwd(xb)
        losses.append(float(xent(logits, yb)))
        accs.append(float(accuracy(logits, yb)))
    loss = sum(losses) / len(losses)
    return {"acc": sum(accs) / len(accs), "loss": loss, "ppl": float(jnp.exp(loss))}


# ----------------------------------------------------------------------
# data plumbing
# ----------------------------------------------------------------------


# datasets.patchy regenerates prototypes from its key; for train/eval we
# need a fixed class structure with fresh samples, so split proto/sample keys:
def _patchy_with(proto_key, sample_key, cfg, n, noise=0.8):
    t, p, c = cfg.seq_len, cfg.patch_dim, cfg.n_classes
    kp, kd = jax.random.split(proto_key)
    protos = jax.random.normal(kp, (c, t, p))
    dbasis = jax.random.normal(kd, (8, t, p)) * 0.7
    ky, km, kn = jax.random.split(sample_key, 3)
    y = jax.random.randint(ky, (n,), 0, c)
    coefs = jax.random.normal(km, (n, 8))
    x = protos[y] + jnp.einsum("nk,ktp->ntp", coefs, dbasis) + noise * jax.random.normal(kn, (n, t, p))
    return x.astype(jnp.float32), y.astype(jnp.int32)


def vision_data_fn(proto_key, cfg):
    """data_fn(key, n) -> (x, y): fixed prototypes, fresh samples per call."""
    return lambda k, n: _patchy_with(proto_key, k, cfg, n)


def lm_data_fn(table, cfg):
    def fn(k, n):
        seqs = datasets.markov(k, cfg, table, n)
        return seqs[:, :-1], seqs[:, 1:]
    return fn
