"""L1 Pallas kernels: grouped vector quantization (paper §2, §3.2).

Encode = nearest-neighbour codebook assignment. The GPU-native formulation
is a per-thread linear scan over centroids; the TPU re-think turns the
distance computation into an MXU matmul:

    ||x - e||^2 = ||x||^2 - 2 x.e^T + ||e||^2

so the [T, K] distance matrix per group is one contraction plus rank-1
updates, and the argmin is a VPU reduction. The grid iterates groups; each
group's codebook slice [K, Dg] is VMEM-resident for the whole group step.

Decode = codebook gather. Gathers are slow on TPU; we instead build a
one-hot [T, K] matrix from a broadcasted iota comparison and contract it
with the codebook — again MXU work (this is exact: one-hot times codebook
selects rows).

interpret=True throughout — see mixed_attention.py.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

INTERPRET = True


def _encode_kernel(x_ref, cb_ref, idx_ref):
    """One group: x_ref [T, Dg], cb_ref [K, Dg] -> idx_ref [T] int32."""
    x = x_ref[0]
    cb = cb_ref[0]
    # squared distances via the matmul identity; ||x||^2 is constant per row
    # and does not affect the argmin, so it is dropped.
    xe = jax.lax.dot_general(
        x, cb, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # [T, K]
    e2 = jnp.sum(cb.astype(jnp.float32) ** 2, axis=-1)  # [K]
    d = e2[None, :] - 2.0 * xe
    idx_ref[0, :] = jnp.argmin(d, axis=-1).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("interpret",))
def grouped_vq_encode(x, codebook, *, interpret: bool = INTERPRET):
    """x: [T, D], codebook: [G, K, Dg] with D = G*Dg -> int32 indices [T, G]."""
    T, D = x.shape
    G, K, Dg = codebook.shape
    assert D == G * Dg, f"D={D} != G*Dg={G}*{Dg}"
    xg = x.reshape(T, G, Dg).transpose(1, 0, 2)  # [G, T, Dg]

    idx = pl.pallas_call(
        _encode_kernel,
        grid=(G,),
        in_specs=[
            pl.BlockSpec((1, T, Dg), lambda g: (g, 0, 0)),
            pl.BlockSpec((1, K, Dg), lambda g: (g, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, T), lambda g: (g, 0)),
        out_shape=jax.ShapeDtypeStruct((G, T), jnp.int32),
        interpret=interpret,
    )(xg, codebook)
    return idx.transpose(1, 0)  # [T, G]


def _decode_kernel(idx_ref, cb_ref, o_ref):
    """One group: idx_ref [T] int32, cb_ref [K, Dg] -> o_ref [T, Dg]."""
    idx = idx_ref[0]
    cb = cb_ref[0]
    K = cb.shape[0]
    onehot = (idx[:, None] == jax.lax.broadcasted_iota(jnp.int32, (1, K), 1)).astype(cb.dtype)
    o_ref[0, :, :] = jax.lax.dot_general(
        onehot, cb, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def grouped_vq_decode(indices, codebook, *, interpret: bool = INTERPRET):
    """indices: [T, G] int32, codebook: [G, K, Dg] -> x_hat [T, G*Dg] f32."""
    T, G = indices.shape
    _, K, Dg = codebook.shape
    idx_g = indices.transpose(1, 0)  # [G, T]

    out = pl.pallas_call(
        _decode_kernel,
        grid=(G,),
        in_specs=[
            pl.BlockSpec((1, T), lambda g: (g, 0)),
            pl.BlockSpec((1, K, Dg), lambda g: (g, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, T, Dg), lambda g: (g, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((G, T, Dg), codebook.dtype),
        interpret=interpret,
    )(idx_g, codebook)
    return out.transpose(1, 0, 2).reshape(T, G * Dg)


def grouped_vq_roundtrip(x, codebook, **kw):
    """encode -> decode; the X_hat consumed by Mixed-Precision Attention."""
    return grouped_vq_decode(grouped_vq_encode(x, codebook, **kw), codebook, **kw)
