"""Pure-jnp reference oracles for the L1 Pallas kernels.

Every Pallas kernel in this package has an exact (up to float associativity)
counterpart here. pytest (python/tests/) sweeps shapes and dtypes with
hypothesis and asserts allclose between kernel and oracle — this file is the
single source of numerical truth for the whole stack: the rust runtime's
outputs are in turn checked against HLO lowered from graphs that call the
kernels, and the pure-rust reference transformer is checked against that.
"""

from __future__ import annotations

import jax.numpy as jnp


def ref_attention(q, k, v, bias=None):
    """Standard scaled dot-product attention.

    q: [H, Tq, dh], k/v: [H, S, dh], bias: [Tq, S] additive (or None).
    Returns [H, Tq, dh].
    """
    dh = q.shape[-1]
    logits = jnp.einsum("hqd,hsd->hqs", q, k) / jnp.sqrt(jnp.float32(dh))
    if bias is not None:
        logits = logits + bias[None, :, :]
    probs = jnp.exp(logits - jnp.max(logits, axis=-1, keepdims=True))
    probs = probs / jnp.sum(probs, axis=-1, keepdims=True)
    return jnp.einsum("hqs,hsd->hqd", probs, v)


def ref_mixed_attention(q, k_local, v_local, k_hat, v_hat, bias=None):
    """Mixed-Precision Attention (paper Eq. 1).

    Local queries attend over the row-wise concatenation [K | K_hat] and
    [V | V_hat]: full-precision local keys/values plus dequantized non-local
    ones. Numerically this is plain attention over the concatenated set; the
    'mixed-precision' structure lives in where K_hat/V_hat came from (the VQ
    decode path) and in what crossed the (simulated) network.

    q: [H, Tq, dh]; k_local/v_local: [H, Tl, dh]; k_hat/v_hat: [H, Tr, dh];
    bias: [Tq, Tl+Tr] additive mask or None.
    """
    k = jnp.concatenate([k_local, k_hat], axis=1)
    v = jnp.concatenate([v_local, v_hat], axis=1)
    return ref_attention(q, k, v, bias)


def ref_grouped_vq_encode(x, codebook):
    """Grouped VQ nearest-neighbour assignment.

    x: [T, D]; codebook: [G, K, D/G]. Returns int32 indices [T, G] where
    indices[t, g] = argmin_k || x[t, g*Dg:(g+1)*Dg] - codebook[g, k] ||^2.
    Ties broken toward the lower index (argmin semantics).
    """
    T, D = x.shape
    G, K, Dg = codebook.shape
    assert D == G * Dg, f"D={D} != G*Dg={G}*{Dg}"
    xg = x.reshape(T, G, Dg)
    # [T, G, K] squared distances
    d = jnp.sum((xg[:, :, None, :] - codebook[None, :, :, :]) ** 2, axis=-1)
    return jnp.argmin(d, axis=-1).astype(jnp.int32)


def ref_grouped_vq_decode(indices, codebook):
    """Grouped VQ decode: indices [T, G] + codebook [G, K, Dg] -> [T, G*Dg]."""
    T, G = indices.shape
    _, _, Dg = codebook.shape
    # gather per group
    gathered = jnp.take_along_axis(
        codebook[None, :, :, :],  # [1, G, K, Dg]
        indices[:, :, None, None].astype(jnp.int32),  # [T, G, 1, 1]
        axis=2,
    )  # [T, G, 1, Dg]
    return gathered.reshape(T, G * Dg)


def ref_grouped_vq_roundtrip(x, codebook):
    """encode then decode — the quantized embedding X_hat used by MPA."""
    return ref_grouped_vq_decode(ref_grouped_vq_encode(x, codebook), codebook)


def ref_layer_norm(x, gamma, beta, eps=1e-5):
    """LayerNorm over the last axis. x: [..., D]."""
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * gamma + beta


def ref_mlp(x, w1, b1, w2, b2):
    """Position-wise feed-forward with GELU (tanh approximation)."""
    h = x @ w1 + b1
    h = 0.5 * h * (1.0 + jnp.tanh(0.7978845608028654 * (h + 0.044715 * h**3)))
    return h @ w2 + b2
