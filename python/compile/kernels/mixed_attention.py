"""L1 Pallas kernel: Mixed-Precision Attention (paper §3.2, Eq. 1).

The paper's GPU hot spot is fused attention of local full-precision queries
over the row-wise concatenation [K | K_hat], [V | V_hat] (local full-precision
plus dequantized non-local VQ keys/values). The CUDA formulation stages K/V
tiles through threadblock shared memory; the TPU/Pallas re-think
(DESIGN.md §Hardware-Adaptation):

  * the grid iterates (head, q-tile, kv-tile); BlockSpec expresses the
    HBM->VMEM schedule that threadblocks did manually;
  * QK^T and PV are MXU contractions over dh-sized tiles;
  * the softmax is the standard *online* (running max / running sum)
    rescaling so a q-tile's accumulator never leaves VMEM while kv-tiles
    stream past;
  * the local/non-local distinction is an additive bias matrix, which also
    carries causal masks for the decoder configuration.

interpret=True everywhere: the CPU PJRT plugin cannot run Mosaic
custom-calls; correctness is asserted against kernels.ref and real-TPU
performance is estimated from the BlockSpec footprint in DESIGN.md §Perf.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# Flag kept in one place so tests and AOT agree; real-TPU builds would flip
# this to False and compile via the TPU plugin instead.
INTERPRET = True

NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, bias_ref, o_ref, m_ref, l_ref, acc_ref, *, kv_steps: int, sm_scale: float):
    """One (head, q-tile, kv-tile) grid step of online-softmax attention.

    q_ref:    [1, bq, dh]   current head's q tile (VMEM)
    k_ref:    [1, bkv, dh]  current kv tile
    v_ref:    [1, bkv, dh]
    bias_ref: [bq, bkv]     additive bias tile (mask / causal / -inf padding)
    o_ref:    [1, bq, dh]   output tile, written on the last kv step
    m/l/acc:  VMEM scratch carried across kv steps (running max, running
              normalizer, unnormalized accumulator)
    """
    kv_i = pl.program_id(2)

    @pl.when(kv_i == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0]  # [bq, dh]
    k = k_ref[0]  # [bkv, dh]
    v = v_ref[0]  # [bkv, dh]

    # MXU contraction; accumulate in f32 regardless of input dtype.
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * sm_scale  # [bq, bkv]
    s = s + bias_ref[...]

    m_prev = m_ref[...]           # [bq]
    m_cur = jnp.max(s, axis=-1)   # [bq]
    m_new = jnp.maximum(m_prev, m_cur)
    # Rescale previous accumulator/normalizer to the new max.
    alpha = jnp.exp(m_prev - m_new)          # [bq]
    p = jnp.exp(s - m_new[:, None])          # [bq, bkv]
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
        p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    m_ref[...] = m_new

    @pl.when(kv_i == kv_steps - 1)
    def _finish():
        o_ref[0, :, :] = (acc_ref[...] / l_ref[...][:, None]).astype(o_ref.dtype)


def _pad_to(x, size, axis, value=0.0):
    pad = size - x.shape[axis]
    if pad <= 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)


@functools.partial(jax.jit, static_argnames=("block_q", "block_kv", "interpret"))
def attention(q, k, v, bias=None, *, block_q: int = 64, block_kv: int = 128, interpret: bool = INTERPRET):
    """Fused multi-head attention via Pallas.

    q: [H, Tq, dh]; k, v: [H, S, dh]; bias: [Tq, S] additive or None.
    Returns [H, Tq, dh] (same dtype as q). Tq and S are padded internally to
    the block sizes; padded kv columns are masked with -inf bias, padded q
    rows are dropped on return.
    """
    H, Tq, dh = q.shape
    S = k.shape[1]
    bq = min(block_q, max(8, Tq))
    bkv = min(block_kv, max(8, S))
    Tq_p = -(-Tq // bq) * bq
    S_p = -(-S // bkv) * bkv

    if bias is None:
        bias = jnp.zeros((Tq, S), dtype=jnp.float32)
    bias = _pad_to(_pad_to(bias.astype(jnp.float32), Tq_p, 0), S_p, 1, NEG_INF)
    q_p = _pad_to(q, Tq_p, 1)
    k_p = _pad_to(k, S_p, 1)
    v_p = _pad_to(v, S_p, 1)

    kv_steps = S_p // bkv
    grid = (H, Tq_p // bq, kv_steps)

    out = pl.pallas_call(
        functools.partial(
            _attn_kernel, kv_steps=kv_steps, sm_scale=1.0 / (dh ** 0.5)
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, dh), lambda h, i, j: (h, i, 0)),
            pl.BlockSpec((1, bkv, dh), lambda h, i, j: (h, j, 0)),
            pl.BlockSpec((1, bkv, dh), lambda h, i, j: (h, j, 0)),
            pl.BlockSpec((bq, bkv), lambda h, i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((1, bq, dh), lambda h, i, j: (h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((H, Tq_p, dh), q.dtype),
        scratch_shapes=[
            # running max, normalizer, accumulator — VMEM residents
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, dh), jnp.float32),
        ],
        interpret=interpret,
    )(q_p, k_p, v_p, bias)
    return out[:, :Tq, :]


def mixed_attention(q, k_local, v_local, k_hat, v_hat, bias=None, **kw):
    """Mixed-Precision Attention: local FP K/V concatenated with dequantized
    non-local K/V (paper Eq. 1), then one fused Pallas attention call.

    Shapes as in kernels.ref.ref_mixed_attention.
    """
    k = jnp.concatenate([k_local, k_hat], axis=1)
    v = jnp.concatenate([v_local, v_hat], axis=1)
    return attention(q, k, v, bias, **kw)
