"""AOT compile path: lower every per-device graph to HLO text + pack weights.

Interchange contract with the rust runtime (rust/src/runtime/):

  artifacts/
    manifest.json     — model/astra config, graph table (file, arg specs,
                        output specs), tensor table (name -> offset/shape
                        into weights.bin), codebook table.
    weights.bin       — all parameters, flat little-endian f32, in the
                        order listed by the manifest tensor table.
    codebooks.bin     — [L, G, K, Dg] f32 flat.
    <graph>.hlo.txt   — HLO *text* per graph (NOT serialized proto: the
                        image's xla_extension 0.5.1 rejects jax>=0.5 64-bit
                        instruction ids; the text parser reassigns them —
                        see /opt/xla-example/README.md).

Graphs are lowered with return_tuple=True; the rust side unwraps the tuple.
Weights are runtime *arguments* (uploaded once as PJRT device buffers), so
one astra_block graph serves all layers and all devices.

Run: `python -m compile.aot --out-dir ../artifacts` (from python/); the
Makefile `artifacts` target does this plus a short fine-tune to produce
non-trivial weights/codebooks (skippable with --random-weights for CI).
"""

from __future__ import annotations

import argparse
import functools
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model, train


def to_hlo_text(fn, *args) -> str:
    """jit-lower fn at the given example args and render XLA HLO text."""
    lowered = jax.jit(fn).lower(*args)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(x):
    return jax.ShapeDtypeStruct(x.shape, x.dtype)


class ArtifactWriter:
    def __init__(self, out_dir: str):
        self.out_dir = out_dir
        os.makedirs(out_dir, exist_ok=True)
        self.graphs = []
        self.tensors = []
        self._weights = []
        self._offset = 0

    def add_tensor(self, name: str, arr) -> dict:
        arr = np.asarray(arr, dtype=np.float32)
        entry = {
            "name": name,
            "offset": self._offset,
            "shape": list(arr.shape),
            "dtype": "f32",
        }
        self.tensors.append(entry)
        self._weights.append(arr.reshape(-1))
        self._offset += arr.size
        return entry

    def add_graph(self, name: str, fn, arg_specs, *, doc: str = ""):
        """arg_specs: list of (arg_name, example_array, kind) where kind in
        {activation, weight, codebook}. Lowers fn and records the table."""
        examples = [jax.ShapeDtypeStruct(a.shape, a.dtype) for _, a, _ in arg_specs]
        text = to_hlo_text(fn, *examples)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(self.out_dir, fname), "w") as f:
            f.write(text)
        outs = jax.eval_shape(fn, *examples)
        if not isinstance(outs, (tuple, list)):
            outs = (outs,)
        self.graphs.append(
            {
                "name": name,
                "file": fname,
                "doc": doc,
                "args": [
                    {"name": n, "shape": list(a.shape), "dtype": str(a.dtype), "kind": k}
                    for n, a, k in arg_specs
                ],
                "outputs": [
                    {"shape": list(o.shape), "dtype": str(o.dtype)} for o in outs
                ],
            }
        )
        return text

    def finish(self, extra: dict):
        flat = (
            np.concatenate(self._weights)
            if self._weights
            else np.zeros((0,), np.float32)
        )
        flat.astype("<f4").tofile(os.path.join(self.out_dir, "weights.bin"))
        manifest = {
            "version": 1,
            "graphs": self.graphs,
            "tensors": self.tensors,
            "weights_file": "weights.bin",
            **extra,
        }
        with open(os.path.join(self.out_dir, "manifest.json"), "w") as f:
            json.dump(manifest, f, indent=1)
        return manifest


def pack_params(w: ArtifactWriter, params, cfg: model.ModelConfig):
    """Write every parameter tensor with stable dotted names."""
    w.add_tensor("pos", params["pos"])
    w.add_tensor("ln_f.g", params["ln_f"]["g"])
    w.add_tensor("ln_f.b", params["ln_f"]["b"])
    if cfg.causal:
        w.add_tensor("embed", params["embed"])
    else:
        w.add_tensor("embed.w", params["embed"]["w"])
        w.add_tensor("embed.b", params["embed"]["b"])
        w.add_tensor("cls", params["cls"])
    w.add_tensor("head.w", params["head"]["w"])
    w.add_tensor("head.b", params["head"]["b"])
    for li, blk in enumerate(params["blocks"]):
        for name, arr in zip(model.BLOCK_WEIGHT_NAMES, model.block_weights_list(blk)):
            w.add_tensor(f"blocks.{li}.{name}", arr)


def build_artifacts(
    out_dir: str,
    cfg: model.ModelConfig,
    acfg: model.AstraConfig,
    *,
    trained=None,
    use_pallas: bool = True,
):
    """Lower all graphs for (cfg, acfg) and write the artifact bundle.

    trained: optional TrainResult carrying fine-tuned params + codebooks;
    otherwise random init (fast path for CI / latency-only work).
    """
    key = jax.random.PRNGKey(42)
    if trained is not None:
        params, codebooks = trained.params, trained.codebooks
    else:
        params = model.init_params(key, cfg)
        codebooks = model.init_codebooks(jax.random.fold_in(key, 1), cfg, acfg)

    w = ArtifactWriter(out_dir)
    pack_params(w, params, cfg)
    np.asarray(codebooks, np.float32).astype("<f4").tofile(
        os.path.join(out_dir, "codebooks.bin")
    )

    d, hh = cfg.d_model, cfg.n_heads
    t, n = cfg.seq_len, acfg.n_devices
    ncls = 1 if (cfg.use_cls and not cfg.causal) else 0
    tc = t // n                 # content tokens per device
    tl = tc + ncls              # local rows (CLS replica first on encoder)
    tr = t - tc                 # remote content tokens
    g, kk = acfg.groups, acfg.codebook_size
    dg = d // g

    f32 = lambda *s: jnp.zeros(s, jnp.float32)
    i32 = lambda *s: jnp.zeros(s, jnp.int32)
    cb_ex = f32(g, kk, dg)
    block_ws = [
        (f"w.{nm}", jnp.asarray(a), "weight")
        for nm, a in zip(model.BLOCK_WEIGHT_NAMES, model.block_weights_list(params["blocks"][0]))
    ]

    # --- per-device MPA block -------------------------------------------
    w.add_graph(
        "astra_block",
        functools.partial(model.astra_block_device, n_heads=hh, use_pallas=use_pallas),
        [
            ("h_local", f32(tl, d), "activation"),
            ("x_hat_remote", f32(tr, d), "activation"),
            ("bias", f32(tl, tl + tr), "activation"),
        ]
        + block_ws,
        doc="one Mixed-Precision Attention transformer block on one device",
    )

    # --- VQ encode/decode ------------------------------------------------
    w.add_graph(
        "vq_encode",
        functools.partial(model.vq_encode_graph, use_pallas=use_pallas),
        [("x", f32(tc, d), "activation"), ("codebook", cb_ex, "codebook")],
        doc="grouped VQ nearest-neighbour assignment for local content tokens",
    )
    w.add_graph(
        "vq_decode",
        functools.partial(model.vq_decode_graph, use_pallas=use_pallas),
        [("idx", i32(tr, g), "activation"), ("codebook", cb_ex, "codebook")],
        doc="grouped VQ decode of received non-local token codes",
    )

    # --- full-sequence baseline block (single-device + ground truth) -----
    t_full = t + ncls
    w.add_graph(
        "baseline_block",
        functools.partial(model.baseline_block, n_heads=hh, use_pallas=use_pallas),
        [("h", f32(t_full, d), "activation"), ("bias", f32(t_full, t_full), "activation")]
        + block_ws,
        doc="full-precision block over the whole sequence",
    )

    # --- embedding + heads ------------------------------------------------
    if cfg.causal:
        w.add_graph(
            "embed_dec",
            model.embed_dec_graph,
            [
                ("onehot_ids", f32(t, cfg.vocab_size), "activation"),
                ("embed", jnp.asarray(params["embed"]), "weight"),
                ("pos", jnp.asarray(params["pos"]), "weight"),
            ],
            doc="decoder token embedding (one-hot matmul) + positions",
        )
        w.add_graph(
            "lm_head",
            model.lm_head_graph,
            [
                ("h", f32(tc, d), "activation"),
                ("ln_f.g", jnp.asarray(params["ln_f"]["g"]), "weight"),
                ("ln_f.b", jnp.asarray(params["ln_f"]["b"]), "weight"),
                ("head.w", jnp.asarray(params["head"]["w"]), "weight"),
                ("head.b", jnp.asarray(params["head"]["b"]), "weight"),
            ],
            doc="final LN + LM head over the tail device's local rows",
        )
        s_max = t
        dh = cfg.d_head
        w.add_graph(
            "decode_step",
            functools.partial(model.decode_step_block, n_heads=hh),
            [
                ("h_t", f32(1, d), "activation"),
                ("k_cache", f32(hh, s_max, dh), "activation"),
                ("v_cache", f32(hh, s_max, dh), "activation"),
                ("valid", f32(s_max), "activation"),
            ]
            + block_ws,
            doc="autoregressive decode: one block, one token, mixed KV cache",
        )
    else:
        w.add_graph(
            "embed_enc",
            model.embed_enc_graph,
            [
                ("patches", f32(t, cfg.patch_dim), "activation"),
                ("embed.w", jnp.asarray(params["embed"]["w"]), "weight"),
                ("embed.b", jnp.asarray(params["embed"]["b"]), "weight"),
                ("pos", jnp.asarray(params["pos"]), "weight"),
            ],
            doc="encoder patch embedding + positions (CLS prepended by leader)",
        )
        w.add_graph(
            "head",
            model.head_graph,
            [
                ("cls_stack", f32(n, d), "activation"),
                ("ln_f.g", jnp.asarray(params["ln_f"]["g"]), "weight"),
                ("ln_f.b", jnp.asarray(params["ln_f"]["b"]), "weight"),
                ("head.w", jnp.asarray(params["head"]["w"]), "weight"),
                ("head.b", jnp.asarray(params["head"]["b"]), "weight"),
            ],
            doc="Distributed Class Token pooling + LN + classifier head",
        )

    return w.finish(
        {
            "model": {
                "n_layers": cfg.n_layers,
                "d_model": d,
                "n_heads": hh,
                "d_ff": cfg.d_ff,
                "seq_len": t,
                "causal": cfg.causal,
                "use_cls": cfg.use_cls,
                "vocab_size": cfg.vocab_size,
                "patch_dim": cfg.patch_dim,
                "n_classes": cfg.n_classes,
            },
            "astra": {
                "n_devices": n,
                "groups": g,
                "codebook_size": kk,
                "bits_per_token": acfg.bits_per_token,
            },
            "codebooks_file": "codebooks.bin",
            "codebooks_shape": [cfg.n_layers, g, kk, dg],
        }
    )


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--train-steps", type=int, default=120,
                    help="fine-tune steps for non-trivial weights (0 = random)")
    ap.add_argument("--no-pallas", action="store_true",
                    help="lower pure-jnp graphs instead of Pallas kernels")
    ap.add_argument("--causal", action="store_true", help="decoder config")
    ap.add_argument("--devices", type=int, default=4)
    ap.add_argument("--groups", type=int, default=16)
    ap.add_argument("--codebook", type=int, default=64)
    args = ap.parse_args()

    cfg = model.ModelConfig(causal=args.causal, use_cls=not args.causal)
    acfg = model.AstraConfig(
        n_devices=args.devices, groups=args.groups, codebook_size=args.codebook
    )

    trained = None
    if args.train_steps > 0:
        key = jax.random.PRNGKey(42)
        if args.causal:
            import jax.numpy as _j
            from . import datasets
            table = datasets.markov_table(jax.random.fold_in(key, 7), cfg.vocab_size)
            data_fn = train.lm_data_fn(table, cfg)
        else:
            data_fn = train.vision_data_fn(jax.random.fold_in(key, 7), cfg)
        print(f"pretraining reference ({args.train_steps} steps)...")
        ref = train.pretrain_reference(key, cfg, data_fn, steps=args.train_steps, log_every=40)
        print("fine-tuning ASTRA...")
        trained = train.finetune_astra(
            jax.random.fold_in(key, 1), ref.params, cfg, acfg, data_fn,
            steps=max(40, args.train_steps // 2), log_every=20,
        )

    manifest = build_artifacts(
        args.out_dir, cfg, acfg, trained=trained, use_pallas=not args.no_pallas
    )
    print(
        f"wrote {len(manifest['graphs'])} graphs, "
        f"{len(manifest['tensors'])} tensors to {args.out_dir}"
    )


if __name__ == "__main__":
    main()
