"""Procedural datasets standing in for the paper's benchmarks (DESIGN.md §2).

The paper fine-tunes on CIFAR-100 / ImageNet-1K (ViT) and Wikipedia /
Wikitext-103 (GPT2). Those are multi-GB downloads unavailable here, so we
use procedurally generated tasks that exercise the identical code paths and
reproduce the tables' *shape* (orderings and relative gaps):

  * patchy(): "vision" — each sample is a grid of patch feature vectors.
    A class is a planted set of per-patch prototype directions; samples are
    prototypes + anisotropic Gaussian noise + global distractor structure.
    Classification needs aggregating evidence across many patches, which is
    exactly what the CLS token does, so VQ-ing cross-device patches hurts
    in the same qualitative way as on CIFAR/ImageNet.

  * markov(): "language" — order-2 Markov chains over a small alphabet with
    sparse, peaked transition tables. Next-token prediction supports a
    nontrivial optimal perplexity; a *different* transition table serves as
    the out-of-domain corpus for the zero-shot row of Table 3 (train on A,
    evaluate on B), reproducing the zero-shot degradation the paper reports.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def patchy(key, cfg, n: int, noise: float = 0.8):
    """n samples of the patch-grid classification task.

    Returns (x [n, T, P] f32, y [n] int32). Class c owns a prototype matrix
    proto[c] [T, P]; a sample is proto[c] + distractor + noise.
    """
    t, p, c = cfg.seq_len, cfg.patch_dim, cfg.n_classes
    kp, kd, kn, ky, km = jax.random.split(key, 5)
    protos = jax.random.normal(kp, (c, t, p)) * 1.0
    y = jax.random.randint(ky, (n,), 0, c)
    # shared distractor subspace (makes the task harder than pure prototypes)
    dbasis = jax.random.normal(kd, (8, t, p)) * 0.7
    coefs = jax.random.normal(km, (n, 8))
    x = (
        protos[y]
        + jnp.einsum("nk,ktp->ntp", coefs, dbasis)
        + noise * jax.random.normal(kn, (n, t, p))
    )
    return x.astype(jnp.float32), y.astype(jnp.int32)


def markov_table(key, vocab: int, peak: float = 12.0):
    """Order-2 transition table [V, V, V] (row-stochastic over last axis)."""
    logits = jax.random.normal(key, (vocab, vocab, vocab)) * peak / 4.0
    # sparsify: keep ~6 plausible successors per context
    thresh = jnp.sort(logits, axis=-1)[..., -6][..., None]
    logits = jnp.where(logits >= thresh, logits, -1e9)
    return jax.nn.softmax(logits, axis=-1)


def markov(key, cfg, table, n: int):
    """n sequences of length seq_len+1 sampled from the order-2 chain.

    Returns int32 [n, T+1]; inputs are [:, :-1], targets [:, 1:].
    """
    t, v = cfg.seq_len, cfg.vocab_size
    k0, k1, ks = jax.random.split(key, 3)
    s0 = jax.random.randint(k0, (n,), 0, v)
    s1 = jax.random.randint(k1, (n,), 0, v)

    def step(carry, key):
        a, b = carry
        probs = table[a, b]  # [n, V]
        nxt = jax.random.categorical(key, jnp.log(probs + 1e-12))
        return (b, nxt), nxt

    keys = jax.random.split(ks, t - 1)
    (_, _), rest = jax.lax.scan(step, (s0, s1), keys)
    return jnp.concatenate([s0[None], s1[None], rest], axis=0).T.astype(jnp.int32)


def optimal_ppl(table, seqs):
    """Perplexity of the true generating chain on seqs — the task's floor."""
    a, b, nxt = seqs[:, :-2], seqs[:, 1:-1], seqs[:, 2:]
    p = table[a, b, nxt]
    return float(jnp.exp(-jnp.mean(jnp.log(p + 1e-12))))
