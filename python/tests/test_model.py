"""L2 model tests: shapes, masks, joint-vs-per-device equivalence, DCT."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.kernels import ref

CFG = model.ModelConfig(
    n_layers=2, d_model=64, n_heads=4, d_ff=128, seq_len=16, patch_dim=12, n_classes=4
)
ACFG = model.AstraConfig(n_devices=4, groups=8, codebook_size=16)
DCFG = model.ModelConfig(
    n_layers=2, d_model=64, n_heads=4, d_ff=128, seq_len=16, causal=True,
    use_cls=False, vocab_size=32,
)


@pytest.fixture(scope="module")
def enc():
    key = jax.random.PRNGKey(0)
    params = model.init_params(key, CFG)
    cbs = model.init_codebooks(jax.random.fold_in(key, 1), CFG, ACFG)
    x = jax.random.normal(jax.random.fold_in(key, 2), (CFG.seq_len, CFG.patch_dim))
    return params, cbs, x


@pytest.fixture(scope="module")
def dec():
    key = jax.random.PRNGKey(3)
    params = model.init_params(key, DCFG)
    cbs = model.init_codebooks(jax.random.fold_in(key, 1), DCFG, ACFG)
    ids = jax.random.randint(jax.random.fold_in(key, 2), (DCFG.seq_len,), 0, DCFG.vocab_size)
    return params, cbs, ids


def test_encoder_shapes(enc):
    params, cbs, x = enc
    logits, aux = model.astra_forward(params, cbs, x, CFG, ACFG)
    assert logits.shape == (CFG.n_classes,)
    assert len(aux["vq_inputs"]) == CFG.n_layers
    ref_logits = model.reference_forward(params, x, CFG)
    assert ref_logits.shape == (CFG.n_classes,)


def test_decoder_shapes(dec):
    params, cbs, ids = dec
    logits, _ = model.astra_forward(params, cbs, ids, DCFG, ACFG)
    assert logits.shape == (DCFG.seq_len, DCFG.vocab_size)


def test_bits_per_token():
    assert model.AstraConfig(groups=1, codebook_size=1024).bits_per_token == 10
    assert model.AstraConfig(groups=16, codebook_size=1024).bits_per_token == 160
    assert model.AstraConfig(groups=32, codebook_size=1024).bits_per_token == 320


def test_make_assign_even_and_hetero():
    a = model.make_assign(CFG, ACFG)
    assert a.shape == (16,)
    assert [int(jnp.sum(a == i)) for i in range(4)] == [4, 4, 4, 4]
    a2 = model.make_assign(CFG, ACFG, sizes=[8, 4, 2, 2])
    assert [int(jnp.sum(a2 == i)) for i in range(4)] == [8, 4, 2, 2]
    with pytest.raises(AssertionError):
        model.make_assign(CFG, ACFG, sizes=[9, 4, 2, 2])


def test_fpar():
    a = model.make_assign(CFG, ACFG)
    assert abs(float(model.fpar(a, 4)) - 0.25) < 1e-6  # even split: 1/N
    a2 = model.make_assign(CFG, ACFG, sizes=[16, 0, 0, 0])
    assert abs(float(model.fpar(a2, 4)) - 1.0) < 1e-6  # all on one device
    # heterogeneity increases FPAR (Appendix D Eq. 36)
    a3 = model.make_assign(CFG, ACFG, sizes=[8, 4, 2, 2])
    assert float(model.fpar(a3, 4)) > 0.25


def test_mixed_bias_structure():
    assign = model.make_assign(CFG, ACFG)
    bias = np.asarray(model.mixed_bias(CFG, ACFG, assign))
    n, t = ACFG.n_devices, CFG.seq_len
    tq = n + t
    assert bias.shape == (tq, n + t + t)
    # CLS replica d: full access to its own device tokens, hat elsewhere
    for d in range(n):
        row = bias[d]
        for j in range(t):  # full content columns
            expect = 0.0 if int(assign[j]) == d else model.NEG
            assert row[n + j] == expect
        for j in range(t):  # hat columns
            expect = model.NEG if int(assign[j]) == d else 0.0
            assert row[n + t + j] == expect
    # content token attends its own full column, not its hat column
    q = n + 0  # first content token (device 0)
    assert bias[q, n + 0] == 0.0
    assert bias[q, n + t + 0] == model.NEG
    # CLS keys: only same replica's queries see them
    assert bias[0, 0] == 0.0 and bias[0, 1] == model.NEG


def test_mixed_bias_causal():
    assign = model.make_assign(DCFG, ACFG)
    bias = np.asarray(model.mixed_bias(DCFG, ACFG, assign))
    t = DCFG.seq_len
    assert bias.shape == (t, 2 * t)
    # no attention to the future in either column block
    for i in range(t):
        for j in range(i + 1, t):
            assert bias[i, j] == model.NEG
            assert bias[i, t + j] == model.NEG
    # token 5 (device 1 owns 4..7): full for 4..5, hat for 0..3
    assert bias[5, 4] == 0.0 and bias[5, 5] == 0.0
    assert bias[5, 0] == model.NEG and bias[5, t + 0] == 0.0


def test_joint_equals_per_device(enc):
    """The joint training graph == composition of per-device AOT graphs."""
    params, cbs, x = enc
    logits, _ = model.astra_forward(params, cbs, x, CFG, ACFG)
    n, t = ACFG.n_devices, CFG.seq_len
    tc = t // n
    h_tok = np.asarray(
        x @ params["embed"]["w"] + params["embed"]["b"] + params["pos"]
    )
    locals_ = [
        np.concatenate([np.asarray(params["cls"]), h_tok[d * tc : (d + 1) * tc]])
        for d in range(n)
    ]
    for li in range(CFG.n_layers):
        content = np.concatenate([l[1:] for l in locals_], axis=0)
        xhat = np.asarray(ref.ref_grouped_vq_roundtrip(jnp.asarray(content), cbs[li]))
        new = []
        for d in range(n):
            remote = np.concatenate(
                [xhat[dd * tc : (dd + 1) * tc] for dd in range(n) if dd != d]
            )
            tl, tr = 1 + tc, t - tc
            bias = jnp.zeros((tl, tl + tr), jnp.float32)
            out = model.astra_block_device(
                jnp.asarray(locals_[d]), jnp.asarray(remote), bias,
                *model.block_weights_list(params["blocks"][li]),
                n_heads=CFG.n_heads, use_pallas=False,
            )
            new.append(np.asarray(out))
        locals_ = new
    cls_stack = jnp.asarray(np.stack([l[0] for l in locals_]))
    logits2 = model.head_graph(
        cls_stack, params["ln_f"]["g"], params["ln_f"]["b"],
        params["head"]["w"], params["head"]["b"],
    )
    np.testing.assert_allclose(np.asarray(logits), np.asarray(logits2), atol=2e-4, rtol=2e-4)


def test_decoder_joint_equals_per_device(dec):
    """Same equivalence for the causal decoder (contiguous partition)."""
    params, cbs, ids = dec
    logits, _ = model.astra_forward(params, cbs, ids, DCFG, ACFG)
    n, t = ACFG.n_devices, DCFG.seq_len
    tc = t // n
    h_tok = np.asarray(params["embed"][ids] + params["pos"])
    locals_ = [h_tok[d * tc : (d + 1) * tc] for d in range(n)]
    for li in range(DCFG.n_layers):
        content = np.concatenate(locals_, axis=0)
        xhat = np.asarray(ref.ref_grouped_vq_roundtrip(jnp.asarray(content), cbs[li]))
        new = []
        for d in range(n):
            remote = np.concatenate(
                [xhat[dd * tc : (dd + 1) * tc] for dd in range(n) if dd != d]
            ) if n > 1 else np.zeros((0, DCFG.d_model), np.float32)
            # causal bias: local rows are positions d*tc..d*tc+tc-1; remote
            # columns are ordered by device then position.
            tl, tr = tc, t - tc
            bias = np.zeros((tl, tl + tr), np.float32)
            for qi in range(tl):
                qpos = d * tc + qi
                for kj in range(tl):
                    if d * tc + kj > qpos:
                        bias[qi, kj] = model.NEG
                col = tl
                for dd in range(n):
                    if dd == d:
                        continue
                    for kj in range(tc):
                        if dd * tc + kj > qpos:
                            bias[qi, col] = model.NEG
                        col += 1
            out = model.astra_block_device(
                jnp.asarray(locals_[d]), jnp.asarray(remote), jnp.asarray(bias),
                *model.block_weights_list(params["blocks"][li]),
                n_heads=DCFG.n_heads, use_pallas=False,
            )
            new.append(np.asarray(out))
        locals_ = new
    h = jnp.asarray(np.concatenate(locals_, axis=0))
    logits2 = model.lm_head_graph(
        h, params["ln_f"]["g"], params["ln_f"]["b"],
        params["head"]["w"], params["head"]["b"],
    )
    np.testing.assert_allclose(np.asarray(logits), np.asarray(logits2), atol=2e-4, rtol=2e-4)


def test_single_cls_differs_from_distributed(enc):
    params, cbs, x = enc
    d_logits, _ = model.astra_forward(params, cbs, x, CFG, ACFG)
    s_logits = model.astra_forward_single_cls(params, cbs, x, CFG, ACFG)
    assert s_logits.shape == d_logits.shape
    assert not np.allclose(np.asarray(d_logits), np.asarray(s_logits))


def test_astra_exact_when_single_device(enc):
    """N=1 means no remote tokens: ASTRA must equal the reference model
    (all attention full-precision, CLS pooling over one replica)."""
    params, cbs, x = enc
    acfg1 = model.AstraConfig(n_devices=1, groups=8, codebook_size=16)
    logits, _ = model.astra_forward(params, cbs, x, CFG, acfg1)
    want = model.reference_forward(params, x, CFG)
    np.testing.assert_allclose(np.asarray(logits), np.asarray(want), atol=1e-4, rtol=1e-4)


def test_decode_step_matches_full_forward(dec):
    """Per-token decode_step over a causal sequence == baseline_block row."""
    params, cbs, ids = dec
    t, d, hh, dh = DCFG.seq_len, DCFG.d_model, DCFG.n_heads, DCFG.d_head
    h = jnp.asarray(params["embed"][ids] + params["pos"])
    pos = jnp.arange(t)
    bias = jnp.where(pos[None, :] <= pos[:, None], 0.0, model.NEG).astype(jnp.float32)
    blk = params["blocks"][0]
    ws = model.block_weights_list(blk)
    want = model.baseline_block(h, bias, *ws, n_heads=DCFG.n_heads, use_pallas=False)

    s_max = t
    k_cache = jnp.zeros((hh, s_max, dh))
    v_cache = jnp.zeros((hh, s_max, dh))
    outs = []
    for i in range(t):
        valid = (jnp.arange(s_max) < i).astype(jnp.float32)
        o, k_new, v_new = model.decode_step_block(
            h[i : i + 1], k_cache, v_cache, valid, *ws, n_heads=DCFG.n_heads
        )
        k_cache = k_cache.at[:, i : i + 1].set(k_new)
        v_cache = v_cache.at[:, i : i + 1].set(v_new)
        outs.append(np.asarray(o)[0])
    np.testing.assert_allclose(np.stack(outs), np.asarray(want), atol=2e-4, rtol=2e-4)
