"""Codebook learning + NAVQ unit tests (compile/vq.py)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import vq as vqlib
from compile.kernels import ref


def _distortion(x, cb):
    xh = ref.ref_grouped_vq_roundtrip(x, cb)
    return float(jnp.mean(jnp.sum((x - xh) ** 2, axis=-1)))


def test_kmeans_reduces_distortion():
    key = jax.random.PRNGKey(0)
    # clustered data: 8 genuine clusters in 16-d
    centers = jax.random.normal(key, (8, 16)) * 3
    assign = jax.random.randint(jax.random.fold_in(key, 1), (512,), 0, 8)
    x = centers[assign] + 0.1 * jax.random.normal(jax.random.fold_in(key, 2), (512, 16))
    cb_rand = jax.random.normal(jax.random.fold_in(key, 3), (2, 8, 8))
    cb_km = vqlib.kmeans_init(jax.random.fold_in(key, 4), x, g=2, k=8)
    assert _distortion(x, cb_km) < 0.5 * _distortion(x, cb_rand)


def test_kmeans_shapes():
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (256, 32))
    cb = vqlib.kmeans_init(key, x, g=4, k=16)
    assert cb.shape == (4, 16, 8)
    assert bool(jnp.all(jnp.isfinite(cb)))


def test_ema_update_moves_toward_data():
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (512, 8)) + 5.0  # data offset from origin
    cb = jax.random.normal(jax.random.fold_in(key, 1), (1, 4, 8))
    counts = jnp.zeros((1, 4))
    sums = jnp.zeros_like(cb)
    d0 = _distortion(x, cb)
    for _ in range(30):
        cb, counts, sums = vqlib.ema_update(cb, counts, sums, x, decay=0.8)
    assert _distortion(x, cb) < d0


def test_straight_through_gradient_is_identity():
    x = jnp.ones((4,)) * 2.0
    x_hat = jnp.ones((4,)) * 7.0

    def f(x):
        return jnp.sum(vqlib.straight_through(x, x_hat) ** 2)

    g = jax.grad(f)(x)
    # d/dx sum(st(x)^2) with st(x) -> values of x_hat but grad flows as x
    np.testing.assert_allclose(np.asarray(g), 2 * np.asarray(x_hat), atol=1e-6)


def test_fit_residual_noise_stats():
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (4096, 4)) * jnp.array([1.0, 2.0, 3.0, 4.0]) + 1.5
    x_hat = jnp.zeros_like(x)
    mu, sigma = vqlib.fit_residual_noise(x, x_hat)
    np.testing.assert_allclose(np.asarray(mu), [1.5] * 4, atol=0.2)
    np.testing.assert_allclose(np.asarray(sigma), [1, 2, 3, 4], atol=0.25)


def test_navq_noise_scales_with_lambda():
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (256, 16))
    cb = jax.random.normal(jax.random.fold_in(key, 1), (2, 4, 8))
    x_hat = ref.ref_grouped_vq_roundtrip(x, cb)
    _, _, commit = vqlib.navq(jax.random.fold_in(key, 2), x, cb, 1.0)
    assert commit > 0
    devs = []
    for lam in [0.0, 0.5, 1.0]:
        x_tilde, _, _ = vqlib.navq(jax.random.fold_in(key, 3), x, cb, lam)
        devs.append(float(jnp.mean(jnp.abs(x_tilde - x_hat))))
    assert devs[0] < 1e-6  # lam=0 -> deterministic quantized values
    assert devs[0] < devs[1] < devs[2]


def test_navq_wasserstein_improvement():
    """Empirical check of Thm 3.1: noise-augmented embeddings are closer in
    distribution (per-dim 1-D W2 on mean/std) to X than raw quantized ones."""
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (2048, 8)) * 1.3 + 0.4
    cb = jax.random.normal(jax.random.fold_in(key, 1), (1, 4, 8)) * 0.3
    x_hat = ref.ref_grouped_vq_roundtrip(x, cb)
    x_tilde, _, _ = vqlib.navq(jax.random.fold_in(key, 2), x, cb, 1.0)

    def gauss_w2(a, b):
        # per-dim Gaussian W2^2 = (mu_a-mu_b)^2 + (sd_a-sd_b)^2
        return float(
            jnp.sum((jnp.mean(a, 0) - jnp.mean(b, 0)) ** 2)
            + jnp.sum((jnp.std(a, 0) - jnp.std(b, 0)) ** 2)
        )

    assert gauss_w2(x, x_tilde) < gauss_w2(x, x_hat)


def test_codebook_utilization():
    idx = jnp.array([[0, 1], [0, 1], [2, 3]], jnp.int32)
    u = vqlib.codebook_utilization(idx, k=8)
    assert abs(float(u) - 4 / 8) < 1e-6
