"""L1 kernel correctness: Pallas (interpret) vs pure-jnp oracles.

Hypothesis sweeps shapes/dtypes; assert_allclose against ref.py — the core
correctness signal for the whole AOT stack.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import mixed_attention as mak
from compile.kernels import ref
from compile.kernels import vq_kernels as vqk

SETTINGS = dict(max_examples=12, deadline=None)


def rng(*keys):
    return [jax.random.PRNGKey(k) for k in keys]


# ---------------------------------------------------------------- attention


@settings(**SETTINGS)
@given(
    h=st.sampled_from([1, 2, 4]),
    tq=st.integers(1, 70),
    s=st.integers(1, 150),
    dh=st.sampled_from([8, 16, 32, 64]),
)
def test_attention_matches_ref(h, tq, s, dh):
    k1, k2, k3 = rng(0, 1, 2)
    q = jax.random.normal(k1, (h, tq, dh), jnp.float32)
    k = jax.random.normal(k2, (h, s, dh), jnp.float32)
    v = jax.random.normal(k3, (h, s, dh), jnp.float32)
    out = mak.attention(q, k, v)
    want = ref.ref_attention(q, k, v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=3e-5, rtol=3e-5)


@settings(**SETTINGS)
@given(
    tq=st.integers(2, 40),
    s=st.integers(2, 90),
    frac=st.floats(0.0, 0.4),
)
def test_attention_with_mask(tq, s, frac):
    k1, k2, k3, k4 = rng(0, 1, 2, 3)
    q = jax.random.normal(k1, (2, tq, 16), jnp.float32)
    k = jax.random.normal(k2, (2, s, 16), jnp.float32)
    v = jax.random.normal(k3, (2, s, 16), jnp.float32)
    mask = jax.random.bernoulli(k4, frac, (tq, s))
    # never mask the entire row (softmax undefined)
    mask = mask.at[:, 0].set(False)
    bias = jnp.where(mask, -1e30, 0.0).astype(jnp.float32)
    out = mak.attention(q, k, v, bias)
    want = ref.ref_attention(q, k, v, bias)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=3e-5, rtol=3e-5)


def test_attention_block_sizes():
    """Same result across q/kv tilings (online softmax invariance)."""
    k1, k2, k3 = rng(0, 1, 2)
    q = jax.random.normal(k1, (2, 50, 32), jnp.float32)
    k = jax.random.normal(k2, (2, 131, 32), jnp.float32)
    v = jax.random.normal(k3, (2, 131, 32), jnp.float32)
    base = np.asarray(mak.attention(q, k, v, block_q=64, block_kv=128))
    for bq, bkv in [(8, 16), (16, 64), (64, 32), (128, 256)]:
        out = np.asarray(mak.attention(q, k, v, block_q=bq, block_kv=bkv))
        np.testing.assert_allclose(out, base, atol=3e-5, rtol=3e-5)


def test_mixed_attention_equals_concat():
    k1, k2, k3, k4, k5 = rng(0, 1, 2, 3, 4)
    h, tq, tl, tr, dh = 2, 9, 9, 24, 16
    q = jax.random.normal(k1, (h, tq, dh))
    kl = jax.random.normal(k2, (h, tl, dh))
    vl = jax.random.normal(k3, (h, tl, dh))
    kr = jax.random.normal(k4, (h, tr, dh))
    vr = jax.random.normal(k5, (h, tr, dh))
    out = mak.mixed_attention(q, kl, vl, kr, vr)
    want = ref.ref_mixed_attention(q, kl, vl, kr, vr)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=3e-5, rtol=3e-5)


def test_attention_causal_bias():
    h, t, dh = 2, 33, 16
    k1, k2, k3 = rng(0, 1, 2)
    q = jax.random.normal(k1, (h, t, dh))
    k = jax.random.normal(k2, (h, t, dh))
    v = jax.random.normal(k3, (h, t, dh))
    pos = jnp.arange(t)
    bias = jnp.where(pos[None, :] <= pos[:, None], 0.0, -1e30).astype(jnp.float32)
    out = mak.attention(q, k, v, bias)
    want = ref.ref_attention(q, k, v, bias)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=3e-5, rtol=3e-5)
    # first row attends only to itself
    np.testing.assert_allclose(np.asarray(out[:, 0]), np.asarray(v[:, 0]), atol=3e-5, rtol=3e-5)


# ---------------------------------------------------------------- VQ


@settings(**SETTINGS)
@given(
    t=st.integers(1, 64),
    g=st.sampled_from([1, 2, 4, 8]),
    k=st.sampled_from([2, 8, 16, 64]),
    dg=st.sampled_from([2, 4, 8, 16]),
)
def test_vq_encode_matches_ref(t, g, k, dg):
    k1, k2 = rng(0, 1)
    x = jax.random.normal(k1, (t, g * dg), jnp.float32)
    cb = jax.random.normal(k2, (g, k, dg), jnp.float32)
    got = np.asarray(vqk.grouped_vq_encode(x, cb))
    want = np.asarray(ref.ref_grouped_vq_encode(x, cb))
    # indices may differ on exact distance ties / float assoc; require the
    # *distances* to agree instead of the raw argmin
    xg = np.asarray(x).reshape(t, g, dg)
    cbn = np.asarray(cb)
    for ti in range(t):
        for gi in range(g):
            dgot = np.sum((xg[ti, gi] - cbn[gi, got[ti, gi]]) ** 2)
            dwant = np.sum((xg[ti, gi] - cbn[gi, want[ti, gi]]) ** 2)
            assert abs(dgot - dwant) < 1e-4, (ti, gi, dgot, dwant)


@settings(**SETTINGS)
@given(
    t=st.integers(1, 64),
    g=st.sampled_from([1, 2, 8]),
    k=st.sampled_from([2, 16, 64]),
)
def test_vq_decode_matches_ref(t, g, k):
    dg = 8
    k1, k2 = rng(0, 1)
    idx = jax.random.randint(k1, (t, g), 0, k).astype(jnp.int32)
    cb = jax.random.normal(k2, (g, k, dg), jnp.float32)
    got = np.asarray(vqk.grouped_vq_decode(idx, cb))
    want = np.asarray(ref.ref_grouped_vq_decode(idx, cb))
    np.testing.assert_allclose(got, want, atol=1e-6)


def test_vq_roundtrip_is_idempotent():
    """Quantizing a quantized vector returns itself."""
    k1, k2 = rng(0, 1)
    x = jax.random.normal(k1, (32, 16), jnp.float32)
    cb = jax.random.normal(k2, (4, 8, 4), jnp.float32)
    xh = vqk.grouped_vq_roundtrip(x, cb)
    xhh = vqk.grouped_vq_roundtrip(xh, cb)
    np.testing.assert_allclose(np.asarray(xh), np.asarray(xhh), atol=1e-5)


def test_vq_encode_exact_centroids():
    """Rows that ARE centroids map to their own index."""
    k2 = jax.random.PRNGKey(1)
    cb = jax.random.normal(k2, (2, 8, 4), jnp.float32)
    x = jnp.concatenate([cb[0, 3], cb[1, 5]])[None, :]  # [1, 8]
    idx = np.asarray(vqk.grouped_vq_encode(x, cb))
    assert idx[0, 0] == 3 and idx[0, 1] == 5
