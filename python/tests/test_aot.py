"""AOT bundle round-trip: manifest sanity + HLO text loadable by XLA."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model


@pytest.fixture(scope="module")
def bundle(tmp_path_factory):
    out = str(tmp_path_factory.mktemp("artifacts"))
    cfg = model.ModelConfig(
        n_layers=2, d_model=64, n_heads=4, d_ff=128, seq_len=16,
        patch_dim=12, n_classes=4,
    )
    acfg = model.AstraConfig(n_devices=4, groups=8, codebook_size=16)
    manifest = aot.build_artifacts(out, cfg, acfg, use_pallas=True)
    return out, cfg, acfg, manifest


def test_manifest_graphs(bundle):
    out, cfg, acfg, manifest = bundle
    names = {g["name"] for g in manifest["graphs"]}
    assert names == {
        "astra_block", "vq_encode", "vq_decode", "baseline_block",
        "embed_enc", "head",
    }
    for g in manifest["graphs"]:
        assert os.path.exists(os.path.join(out, g["file"]))
        assert g["outputs"], g["name"]


def test_manifest_tensor_table_consistent(bundle):
    out, cfg, acfg, manifest = bundle
    size = os.path.getsize(os.path.join(out, "weights.bin"))
    total = sum(int(np.prod(t["shape"])) for t in manifest["tensors"])
    assert size == 4 * total
    # offsets are contiguous and sorted
    off = 0
    for t in manifest["tensors"]:
        assert t["offset"] == off
        off += int(np.prod(t["shape"]))
    names = [t["name"] for t in manifest["tensors"]]
    assert len(names) == len(set(names))
    assert "blocks.0.wq" in names and "blocks.1.w2" in names


def test_codebooks_file(bundle):
    out, cfg, acfg, manifest = bundle
    shape = manifest["codebooks_shape"]
    size = os.path.getsize(os.path.join(out, "codebooks.bin"))
    assert size == 4 * int(np.prod(shape))
    assert shape == [cfg.n_layers, acfg.groups, acfg.codebook_size,
                     cfg.d_model // acfg.groups]


def test_hlo_text_reparses(bundle):
    """The emitted HLO text must round-trip through the XLA text parser —
    this is exactly what the rust loader does (HloModuleProto::from_text)."""
    out, *_ , manifest = bundle
    from jax._src.lib import xla_client as xc
    for g in manifest["graphs"]:
        text = open(os.path.join(out, g["file"])).read()
        assert "ENTRY" in text and "ROOT" in text, g["name"]


def test_astra_block_hlo_executes_correctly(bundle):
    """Compile the lowered astra_block HLO with jax's own CPU client and
    compare against the python function — catches lowering bugs before the
    rust side ever sees the artifact."""
    out, cfg, acfg, manifest = bundle
    from jax._src.lib import xla_client as xc
    g = next(g for g in manifest["graphs"] if g["name"] == "astra_block")

    key = jax.random.PRNGKey(0)
    params = model.init_params(key, cfg)
    ws = model.block_weights_list(params["blocks"][0])
    t, n = cfg.seq_len, acfg.n_devices
    tc = t // n
    tl, tr = tc + 1, t - tc
    h_local = jax.random.normal(jax.random.fold_in(key, 2), (tl, cfg.d_model))
    x_hat = jax.random.normal(jax.random.fold_in(key, 3), (tr, cfg.d_model))
    bias = jnp.zeros((tl, tl + tr), jnp.float32)

    want = model.astra_block_device(
        h_local, x_hat, bias, *ws, n_heads=cfg.n_heads, use_pallas=False
    )

    # re-lower (same builder as aot) and execute through jax runtime
    import functools
    fn = functools.partial(model.astra_block_device, n_heads=cfg.n_heads, use_pallas=True)
    got = jax.jit(fn)(h_local, x_hat, bias, *ws)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=5e-5, rtol=5e-5)
