"""Training harness smoke tests (fast versions of the accuracy pipeline)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import datasets, model, train

CFG = model.ModelConfig(
    n_layers=2, d_model=64, n_heads=4, d_ff=128, seq_len=16, patch_dim=12, n_classes=4
)
ACFG = model.AstraConfig(n_devices=4, groups=8, codebook_size=16)


def test_adam_decreases_quadratic():
    params = {"w": jnp.array([5.0, -3.0])}
    opt = train.adam_init(params)
    loss = lambda p: jnp.sum(p["w"] ** 2)
    for _ in range(200):
        g = jax.grad(loss)(params)
        params, opt = train.adam_update(g, opt, params, lr=0.1)
    assert float(loss(params)) < 1e-3


def test_xent_and_accuracy():
    logits = jnp.array([[10.0, 0.0], [0.0, 10.0]])
    y = jnp.array([0, 1])
    assert float(train.xent(logits, y)) < 1e-3
    assert float(train.accuracy(logits, y)) == 1.0
    y_bad = jnp.array([1, 0])
    assert float(train.accuracy(logits, y_bad)) == 0.0


def test_pretrain_reduces_loss():
    key = jax.random.PRNGKey(0)
    data_fn = train.vision_data_fn(jax.random.fold_in(key, 7), CFG)
    res = train.pretrain_reference(key, CFG, data_fn, steps=30, batch=16)
    assert res.metrics["final_loss"] < 1.3  # ln(4) = 1.386 is chance


def test_finetune_astra_runs_and_improves_over_random_codebooks():
    key = jax.random.PRNGKey(0)
    data_fn = train.vision_data_fn(jax.random.fold_in(key, 7), CFG)
    ref = train.pretrain_reference(key, CFG, data_fn, steps=30, batch=16)
    # random codebooks, no fine-tune
    cbs0 = model.init_codebooks(jax.random.fold_in(key, 5), CFG, ACFG)
    m0 = train.eval_astra(ref.params, cbs0, CFG, ACFG, data_fn,
                          jax.random.fold_in(key, 9), n_batches=2, batch=16)
    ft = train.finetune_astra(
        jax.random.fold_in(key, 1), ref.params, CFG, ACFG, data_fn,
        steps=25, batch=16,
    )
    m1 = train.eval_astra(ft.params, ft.codebooks, CFG, ACFG, data_fn,
                          jax.random.fold_in(key, 9), n_batches=2, batch=16)
    assert m1["acc"] >= m0["acc"]
    assert ft.codebooks.shape == (CFG.n_layers, ACFG.groups, ACFG.codebook_size,
                                  CFG.d_model // ACFG.groups)


def test_markov_dataset_properties():
    key = jax.random.PRNGKey(0)
    dcfg = model.ModelConfig(seq_len=32, causal=True, use_cls=False, vocab_size=16)
    table = datasets.markov_table(key, dcfg.vocab_size)
    np.testing.assert_allclose(
        np.asarray(jnp.sum(table, axis=-1)), 1.0, atol=1e-5
    )
    seqs = datasets.markov(jax.random.fold_in(key, 1), dcfg, table, n=8)
    assert seqs.shape == (8, 33)
    assert int(seqs.min()) >= 0 and int(seqs.max()) < 16
    # the generating chain beats uniform
    assert datasets.optimal_ppl(table, seqs) < 16


def test_patchy_dataset_learnable_structure():
    key = jax.random.PRNGKey(0)
    x, y = datasets.patchy(key, CFG, n=64)
    assert x.shape == (64, CFG.seq_len, CFG.patch_dim)
    assert y.shape == (64,)
    # same-class samples are closer than cross-class on average
    x0 = x[y == int(y[0])]
    xo = x[y != int(y[0])]
    if len(x0) > 1 and len(xo) > 0:
        d_same = float(jnp.mean(jnp.linalg.norm(x0[0] - x0[1:], axis=(1, 2))))
        d_diff = float(jnp.mean(jnp.linalg.norm(x0[0] - xo, axis=(1, 2))))
        assert d_same < d_diff


def test_collect_embeddings_shapes():
    key = jax.random.PRNGKey(0)
    data_fn = train.vision_data_fn(jax.random.fold_in(key, 7), CFG)
    params = model.init_params(key, CFG)
    embs = train.collect_embeddings(key, params, CFG, ACFG, data_fn, n_batches=1, batch=4)
    assert len(embs) == CFG.n_layers
    assert embs[0].shape == (4 * CFG.seq_len, CFG.d_model)
