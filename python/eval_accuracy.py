"""Regenerate the paper's accuracy tables at reproduction scale.

Tables (analogues at AstraFormer scale on procedural datasets; DESIGN.md §2
documents the substitution, EXPERIMENTS.md the outcomes):

  --table 1   vision accuracy vs #groups (+ zero-VQ reference row)
  --table 2   accuracy vs device count
  --table 3   LM perplexity vs #groups, fine-tuned + zero-shot corpus
  --table 8   seed robustness (mean/std over seeds)
  --table 9   FPAR vs accuracy under random heterogeneous assignment
  --table 11  perplexity under packet loss (stale-code fallback)
  --table 12  NAVQ lambda sweep (train/val gap)
  --table 13  distributed vs single class token
  --table 14  commitment beta sweep
  --table 15  codebook size sweep

`--fast` shrinks steps/batches ~4x (smoke scale); default is the
EXPERIMENTS.md reporting scale. Results print as tables and are written to
../results/acc_table<N>.csv.

Build-time python only — nothing here runs on the serving path.
"""

from __future__ import annotations

import argparse
import os
import sys

import jax
import jax.numpy as jnp

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))

from compile import datasets, model, train  # noqa: E402

RESULTS = os.path.join(os.path.dirname(os.path.abspath(__file__)), "..", "results")


def save(name, header, rows):
    os.makedirs(RESULTS, exist_ok=True)
    with open(os.path.join(RESULTS, f"{name}.csv"), "w") as f:
        f.write(header + "\n")
        for r in rows:
            f.write(",".join(str(x) for x in r) + "\n")


def cfg_vision(fast):
    return model.ModelConfig(
        n_layers=2 if fast else 3, d_model=96 if fast else 128, n_heads=4,
        d_ff=256 if fast else 384, seq_len=32 if fast else 64,
        patch_dim=24, n_classes=8,
    )


def cfg_lm(fast):
    return model.ModelConfig(
        n_layers=2, d_model=96 if fast else 128, n_heads=4,
        d_ff=256 if fast else 384, seq_len=32 if fast else 64,
        causal=True, use_cls=False, vocab_size=32,
    )


def steps(fast, n):
    return max(10, n // 4) if fast else n


GROUPS = [1, 4, 16]  # analogue of the paper's {1, 16, 32} at D=128


def pretrain_vision(key, cfg, fast):
    data = train.vision_data_fn(jax.random.fold_in(key, 7), cfg)
    ref = train.pretrain_reference(key, cfg, data, steps=steps(fast, 240))
    return ref, data


# ----------------------------------------------------------------- tables


def table1(key, fast):
    cfg = cfg_vision(fast)
    ref, data = pretrain_vision(key, cfg, fast)
    m_ref = train.eval_reference(ref.params, cfg, data, jax.random.fold_in(key, 9))
    print(f"\n== Table 1 analogue: accuracy vs #groups (reference {m_ref['acc']:.4f}) ==")
    rows = [["reference", "-", "-", f"{m_ref['acc']:.4f}"]]
    for g in GROUPS:
        acfg = model.AstraConfig(n_devices=4, groups=g, codebook_size=64)
        ft = train.finetune_astra(jax.random.fold_in(key, g), ref.params, cfg, acfg,
                                  data, steps=steps(fast, 160))
        m = train.eval_astra(ft.params, ft.codebooks, cfg, acfg, data,
                             jax.random.fold_in(key, 9))
        bits = acfg.bits_per_token * cfg.n_layers
        comp = 32 * cfg.d_model / acfg.bits_per_token
        print(f"  G={g:<3} bits/tok={bits:<6} comp={comp:7.1f}x  acc={m['acc']:.4f}")
        rows.append([g, bits, f"{comp:.1f}", f"{m['acc']:.4f}"])
    save("acc_table1", "groups,total_bits_per_token,compression,accuracy", rows)


def table2(key, fast):
    cfg = cfg_vision(fast)
    ref, data = pretrain_vision(key, cfg, fast)
    m_ref = train.eval_reference(ref.params, cfg, data, jax.random.fold_in(key, 9))
    print(f"\n== Table 2 analogue: accuracy vs #devices (reference {m_ref['acc']:.4f}) ==")
    rows = [["1(ref)", f"{m_ref['acc']:.4f}"]]
    for n in [2, 4, 8]:
        acfg = model.AstraConfig(n_devices=n, groups=GROUPS[-1], codebook_size=64)
        ft = train.finetune_astra(jax.random.fold_in(key, 100 + n), ref.params, cfg,
                                  acfg, data, steps=steps(fast, 160))
        m = train.eval_astra(ft.params, ft.codebooks, cfg, acfg, data,
                             jax.random.fold_in(key, 9))
        print(f"  N={n}: acc={m['acc']:.4f}")
        rows.append([n, f"{m['acc']:.4f}"])
    save("acc_table2", "devices,accuracy", rows)


def table3(key, fast):
    cfg = cfg_lm(fast)
    kt = jax.random.fold_in(key, 70)
    table_a = datasets.markov_table(kt, cfg.vocab_size)
    table_b = datasets.markov_table(jax.random.fold_in(kt, 1), cfg.vocab_size)
    data_a = train.lm_data_fn(table_a, cfg)
    data_b = train.lm_data_fn(table_b, cfg)
    ref = train.pretrain_reference(key, cfg, data_a, steps=steps(fast, 240))
    m_ref = train.eval_reference(ref.params, cfg, data_a, jax.random.fold_in(key, 9))
    m_ref_zs = train.eval_reference(ref.params, cfg, data_b, jax.random.fold_in(key, 9))
    print(f"\n== Table 3 analogue: PPL vs #groups "
          f"(reference {m_ref['ppl']:.3f}, zero-shot {m_ref_zs['ppl']:.3f}) ==")
    rows = [["reference", f"{m_ref['ppl']:.4f}", f"{m_ref_zs['ppl']:.4f}"]]
    for g in GROUPS:
        acfg = model.AstraConfig(n_devices=4, groups=g, codebook_size=64)
        ft = train.finetune_astra(jax.random.fold_in(key, 200 + g), ref.params, cfg,
                                  acfg, data_a, steps=steps(fast, 160))
        m = train.eval_astra(ft.params, ft.codebooks, cfg, acfg, data_a,
                             jax.random.fold_in(key, 9))
        m_zs = train.eval_astra(ft.params, ft.codebooks, cfg, acfg, data_b,
                                jax.random.fold_in(key, 9))
        print(f"  G={g:<3} PPL={m['ppl']:.3f}  zero-shot PPL={m_zs['ppl']:.3f}")
        rows.append([g, f"{m['ppl']:.4f}", f"{m_zs['ppl']:.4f}"])
    save("acc_table3", "groups,ppl_finetuned,ppl_zeroshot", rows)


def table8(key, fast):
    cfg = cfg_vision(fast)
    ref, data = pretrain_vision(key, cfg, fast)
    print("\n== Table 8 analogue: seed robustness (G=max) ==")
    seeds = range(3 if fast else 5)
    accs = []
    for s in seeds:
        acfg = model.AstraConfig(n_devices=4, groups=GROUPS[-1], codebook_size=64)
        ft = train.finetune_astra(jax.random.PRNGKey(1000 + s), ref.params, cfg,
                                  acfg, data, steps=steps(fast, 120))
        m = train.eval_astra(ft.params, ft.codebooks, cfg, acfg, data,
                             jax.random.fold_in(key, 9))
        accs.append(m["acc"])
        print(f"  seed {s}: acc={m['acc']:.4f}")
    mean = sum(accs) / len(accs)
    std = (sum((a - mean) ** 2 for a in accs) / len(accs)) ** 0.5
    print(f"  mean={mean:.4f} std={std:.4f}")
    save("acc_table8", "seed,accuracy",
         [[i, f"{a:.4f}"] for i, a in enumerate(accs)] + [["mean", f"{mean:.4f}"], ["std", f"{std:.4f}"]])


def table9(key, fast):
    cfg = cfg_vision(fast)
    ref, data = pretrain_vision(key, cfg, fast)
    acfg = model.AstraConfig(n_devices=4, groups=GROUPS[-1], codebook_size=64)
    ft = train.finetune_astra(jax.random.fold_in(key, 5), ref.params, cfg, acfg,
                              data, steps=steps(fast, 160), random_assign=True)
    print("\n== Table 9 analogue: FPAR vs accuracy (random assignment) ==")
    # evaluate per-batch with random assignments, bin by FPAR
    records = []
    kd = jax.random.fold_in(key, 9)
    for _ in range(12 if fast else 40):
        kd, ka, kb = jax.random.split(kd, 3)
        assign = jax.random.randint(ka, (cfg.seq_len,), 0, 4).astype(jnp.int32)
        f = float(model.fpar(assign, 4))
        m = train.eval_astra(ft.params, ft.codebooks, cfg, acfg, data, kb,
                             assign=assign, n_batches=1, batch=32)
        records.append((f, m["acc"]))
    records.sort()
    nbins = 4
    rows = []
    per = len(records) // nbins
    for b in range(nbins):
        chunk = records[b * per:(b + 1) * per] or records[-1:]
        f_lo, f_hi = chunk[0][0], chunk[-1][0]
        acc = sum(a for _, a in chunk) / len(chunk)
        print(f"  FPAR [{f_lo:.3f}, {f_hi:.3f}]: acc={acc:.4f}")
        rows.append([f"{f_lo:.4f}", f"{f_hi:.4f}", f"{acc:.4f}"])
    save("acc_table9", "fpar_lo,fpar_hi,accuracy", rows)


def table11(key, fast):
    """Packet loss: at eval time, a fraction of non-local token codes is
    replaced by the previous layer's codes (stale fallback), mirroring the
    rust coordinator's loss path."""
    cfg = cfg_vision(fast)
    ref, data = pretrain_vision(key, cfg, fast)
    acfg = model.AstraConfig(n_devices=4, groups=GROUPS[-1], codebook_size=64)
    ft = train.finetune_astra(jax.random.fold_in(key, 6), ref.params, cfg, acfg,
                              data, steps=steps(fast, 160))
    print("\n== Table 11 analogue: accuracy under packet loss ==")
    from compile.kernels import ref as refk

    def eval_with_loss(loss_p, key):
        # joint forward but x_tilde rows replaced with *previous layer's*
        # quantized rows at loss_p rate
        def fwd(x, k):
            assign = model.make_assign(cfg, acfg)
            h_tok = model._embed(ft.params, cfg, x)
            n = acfg.n_devices
            h = jnp.concatenate([jnp.tile(ft.params["cls"], (n, 1)), h_tok], axis=0)
            bias = model.mixed_bias(cfg, acfg, assign)
            prev = None
            for li, blk in enumerate(ft.params["blocks"]):
                content = h[n:]
                x_hat = refk.ref_grouped_vq_roundtrip(content, ft.codebooks[li])
                if prev is not None and loss_p > 0:
                    k, kl = jax.random.split(k)
                    drop = jax.random.bernoulli(kl, loss_p, (content.shape[0], 1))
                    x_hat = jnp.where(drop, prev, x_hat)
                prev = x_hat
                ln1 = lambda y: refk.ref_layer_norm(y, blk["ln1"]["g"], blk["ln1"]["b"])
                q, kf, vf = model._project_qkv(blk, ln1(h))
                _, kh, vh = model._project_qkv(blk, ln1(x_hat))
                hh = cfg.n_heads
                out = model._attn_jnp(
                    model._split_heads(q, hh),
                    jnp.concatenate([model._split_heads(kf, hh), model._split_heads(kh, hh)], axis=1),
                    jnp.concatenate([model._split_heads(vf, hh), model._split_heads(vh, hh)], axis=1),
                    bias,
                )
                h = h + model._merge_heads(out) @ blk["wo"] + blk["bo"]
                h = h + model._mlp(blk, h)
            lnf = lambda y: refk.ref_layer_norm(y, ft.params["ln_f"]["g"], ft.params["ln_f"]["b"])
            return lnf(jnp.mean(h[:n], axis=0)) @ ft.params["head"]["w"] + ft.params["head"]["b"]

        accs = []
        for _ in range(4):
            key, kb, kf_ = jax.random.split(key, 3)
            xb, yb = data(kb, 32)
            logits = jax.vmap(fwd, in_axes=(0, None))(xb, kf_)
            accs.append(float(train.accuracy(logits, yb)))
        return sum(accs) / len(accs)

    rows = []
    for p in [0.0, 0.05, 0.2]:
        acc = eval_with_loss(p, jax.random.fold_in(key, 9))
        print(f"  loss={p:.2f}: acc={acc:.4f}")
        rows.append([p, f"{acc:.4f}"])
    save("acc_table11", "loss_rate,accuracy", rows)


def table12(key, fast):
    cfg = cfg_vision(fast)
    ref, data = pretrain_vision(key, cfg, fast)
    print("\n== Table 12 analogue: NAVQ lambda sweep ==")
    rows = []
    for lam in [0.0, 0.1, 0.3, 1.0]:
        acfg = model.AstraConfig(n_devices=4, groups=GROUPS[1], codebook_size=64,
                                 noise_lambda=lam)
        ft = train.finetune_astra(jax.random.fold_in(key, 30), ref.params, cfg,
                                  acfg, data, steps=steps(fast, 160))
        m_tr = train.eval_astra(ft.params, ft.codebooks, cfg, acfg, data,
                                jax.random.fold_in(key, 7), n_batches=4)
        m_va = train.eval_astra(ft.params, ft.codebooks, cfg, acfg, data,
                                jax.random.fold_in(key, 9), n_batches=4)
        print(f"  lambda={lam}: train={m_tr['acc']:.4f} val={m_va['acc']:.4f} "
              f"gap={m_tr['acc'] - m_va['acc']:+.4f}")
        rows.append([lam, f"{m_tr['acc']:.4f}", f"{m_va['acc']:.4f}"])
    save("acc_table12", "lambda,train_acc,val_acc", rows)


def table13(key, fast):
    cfg = cfg_vision(fast)
    ref, data = pretrain_vision(key, cfg, fast)
    print("\n== Table 13 analogue: distributed vs single class token ==")
    rows = []
    for g in GROUPS:
        acfg = model.AstraConfig(n_devices=4, groups=g, codebook_size=64)
        ft_d = train.finetune_astra(jax.random.fold_in(key, 40 + g), ref.params, cfg,
                                    acfg, data, steps=steps(fast, 160))
        m_d = train.eval_astra(ft_d.params, ft_d.codebooks, cfg, acfg, data,
                               jax.random.fold_in(key, 9))
        # single-CLS: same codebooks (frozen), single-token forward
        ft_s = train.finetune_astra(jax.random.fold_in(key, 50 + g), ref.params, cfg,
                                    acfg, data, steps=steps(fast, 160), single_cls=True,
                                    ema_codebooks=False)
        # reuse distributed run's codebooks for the single-CLS eval
        m_s = train.eval_astra(ft_s.params, ft_d.codebooks, cfg, acfg, data,
                               jax.random.fold_in(key, 9), single_cls=True)
        print(f"  G={g:<3} single={m_s['acc']:.4f} dist={m_d['acc']:.4f} "
              f"delta={m_d['acc'] - m_s['acc']:+.4f}")
        rows.append([g, f"{m_s['acc']:.4f}", f"{m_d['acc']:.4f}"])
    save("acc_table13", "groups,single_cls_acc,distributed_cls_acc", rows)


def table14(key, fast):
    cfg = cfg_vision(fast)
    ref, data = pretrain_vision(key, cfg, fast)
    print("\n== Table 14 analogue: commitment beta sweep ==")
    rows = []
    for beta in [0.0, 2e-4, 0.25]:
        acfg = model.AstraConfig(n_devices=4, groups=GROUPS[1], codebook_size=64,
                                 commit_beta=beta)
        ft = train.finetune_astra(jax.random.fold_in(key, 60), ref.params, cfg,
                                  acfg, data, steps=steps(fast, 160))
        m = train.eval_astra(ft.params, ft.codebooks, cfg, acfg, data,
                             jax.random.fold_in(key, 9))
        print(f"  beta={beta}: acc={m['acc']:.4f}")
        rows.append([beta, f"{m['acc']:.4f}"])
    save("acc_table14", "beta,accuracy", rows)


def table15(key, fast):
    cfg = cfg_vision(fast)
    ref, data = pretrain_vision(key, cfg, fast)
    print("\n== Table 15 analogue: codebook size sweep (G=max) ==")
    rows = []
    for k in [16, 64, 256]:
        acfg = model.AstraConfig(n_devices=4, groups=GROUPS[-1], codebook_size=k)
        ft = train.finetune_astra(jax.random.fold_in(key, 80 + k), ref.params, cfg,
                                  acfg, data, steps=steps(fast, 160))
        m = train.eval_astra(ft.params, ft.codebooks, cfg, acfg, data,
                             jax.random.fold_in(key, 9))
        comp = 32 * cfg.d_model / acfg.bits_per_token
        print(f"  K={k:<4} comp={comp:7.1f}x acc={m['acc']:.4f}")
        rows.append([k, f"{comp:.1f}", f"{m['acc']:.4f}"])
    save("acc_table15", "codebook_size,compression,accuracy", rows)


TABLES = {
    1: table1, 2: table2, 3: table3, 8: table8, 9: table9,
    11: table11, 12: table12, 13: table13, 14: table14, 15: table15,
}


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--table", type=int, default=0, help="0 = all")
    ap.add_argument("--fast", action="store_true")
    args = ap.parse_args()
    key = jax.random.PRNGKey(42)
    if args.table:
        TABLES[args.table](key, args.fast)
    else:
        for t, fn in TABLES.items():
            fn(jax.random.fold_in(key, t), args.fast)


if __name__ == "__main__":
    main()
